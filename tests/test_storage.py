"""KV backends, columnar codec, crash recovery, checkpointing."""
import json
import os

import numpy as np
import pytest

import jax.numpy as jnp

from repro.core.deltas import AttrDelta, Delta
from repro.storage import columnar as col
from repro.storage.checkpoint import (latest_step, restore_checkpoint,
                                      restore_param_history,
                                      save_checkpoint, save_param_delta)
from repro.storage.kv import LogFileKV, MemKV, PartitionedKV


def test_columnar_roundtrip():
    rng = np.random.default_rng(0)
    arrays = {"a": rng.integers(0, 100, 17).astype(np.int32),
              "b": rng.standard_normal((3, 5)).astype(np.float32),
              "c": np.zeros(0, np.int16)}
    blob = col.pack_arrays(arrays)
    out = col.unpack_arrays(blob)
    for k in arrays:
        assert np.array_equal(out[k], arrays[k])
        assert out[k].dtype == arrays[k].dtype


def test_delta_codec_roundtrip():
    rng = np.random.default_rng(1)
    d = Delta(rng.integers(0, 50, 5).astype(np.int32),
              rng.integers(0, 50, 3).astype(np.int32),
              rng.integers(0, 90, 7).astype(np.int32),
              np.zeros(0, np.int32),
              AttrDelta(np.array([1, 2], np.int32), np.array([0, 1], np.int16),
                        np.array([1.5, 2.5], np.float32),
                        np.array([np.nan, 0.5], np.float32)),
              AttrDelta.empty())
    parts = col.encode_delta(d)
    d2 = col.decode_delta(parts)
    assert np.array_equal(d2.node_add, d.node_add)
    assert np.array_equal(d2.edge_add, d.edge_add)
    assert np.array_equal(d2.node_attr.new, d.node_attr.new)
    assert np.array_equal(d2.node_attr.old, d.node_attr.old, equal_nan=True)


@pytest.mark.parametrize("make", [MemKV, None])
def test_kv_backends(tmp_path, make):
    kv = make() if make else LogFileKV(str(tmp_path / "kv"))
    kv.put((0, 1, "struct"), b"hello")
    kv.put((2, 7, "nodeattr.3"), b"world" * 100)
    assert kv.get((0, 1, "struct")) == b"hello"
    assert (2, 7, "nodeattr.3") in kv
    assert (9, 9, "x") not in kv
    assert set(kv.keys()) == {(0, 1, "struct"), (2, 7, "nodeattr.3")}
    kv.put((0, 1, "struct"), b"hello2")  # overwrite
    assert kv.get((0, 1, "struct")) == b"hello2"
    assert kv.stats.puts == 3
    kv.close()


def test_logfile_kv_reopen_and_torn_tail(tmp_path):
    path = str(tmp_path / "kv")
    kv = LogFileKV(path)
    kv.put((0, 1, "a"), b"x" * 100)
    kv.flush()
    kv.put((0, 2, "b"), b"y" * 100)   # not flushed into the index
    kv._fh.flush()
    kv._fh.close()
    # simulate a crash with a torn tail record
    with open(os.path.join(path, "kv.log"), "ab") as f:
        f.write(b"RKV1\x05\x00\x00\x00abc")  # truncated record
    kv2 = LogFileKV(path)
    assert kv2.get((0, 1, "a")) == b"x" * 100
    assert kv2.get((0, 2, "b")) == b"y" * 100  # recovered unflushed record
    assert (0, 3, "c") not in kv2
    kv2.close()


def test_partitioned_kv(tmp_path):
    kv = PartitionedKV([MemKV(), MemKV(), MemKV()])
    for p in range(3):
        kv.put((p, 0, "struct"), bytes([p]))
    assert all(kv.get((p, 0, "struct")) == bytes([p]) for p in range(3))
    assert len(kv.parts[1].keys()) == 1


def test_checkpoint_roundtrip(tmp_path):
    store = LogFileKV(str(tmp_path / "ckpt"))
    tree = {"w": jnp.arange(12, dtype=jnp.float32).reshape(3, 4),
            "nested": {"b": jnp.ones(5, jnp.bfloat16)},
            "step": jnp.int32(7)}
    save_checkpoint(store, 100, tree, extra={"data_cursor": 12345},
                    n_shards=2)
    assert latest_step(store) == 100
    got, extra, step = restore_checkpoint(store, like=tree)
    assert step == 100 and extra["data_cursor"] == 12345
    assert np.array_equal(np.asarray(got["w"]), np.arange(12).reshape(3, 4))
    assert got["nested"]["b"].dtype == jnp.bfloat16


def test_checkpoint_crash_keeps_previous(tmp_path):
    store = LogFileKV(str(tmp_path / "ckpt"))
    tree = {"w": jnp.zeros(4)}
    save_checkpoint(store, 1, tree)
    # a partial later checkpoint without manifest/latest commit
    store.put((0, 2, "ckpt/w/0"), b"garbage-partial")
    got, _, step = restore_checkpoint(store, like=tree)
    assert step == 1


def test_param_delta_history(tmp_path):
    store = MemKV()
    t0 = {"w": np.arange(10, dtype=np.float32)}
    t1 = {"w": t0["w"].copy()}
    t1["w"][3] = 99.0
    t2 = {"w": t1["w"].copy()}
    t2["w"][7] = -1.0
    save_param_delta(store, 0, None, t0)
    b1 = save_param_delta(store, 1, 0, t1, t0)
    b2 = save_param_delta(store, 2, 1, t2, t1)
    full = save_param_delta(MemKV(), 0, None, t2)
    assert b1 < 200 and b2 < 200  # sparse deltas are tiny
    hist = restore_param_history(store, [0, 1, 2], like=t0)
    assert hist[1]["w"][3] == 99.0 and hist[1]["w"][7] == 7.0
    assert hist[2]["w"][7] == -1.0
