"""KV backends, columnar codec, crash recovery, checkpointing."""
import json
import os

import numpy as np
import pytest

import jax.numpy as jnp

from repro.core.deltas import AttrDelta, Delta
from repro.storage import columnar as col
from repro.storage.checkpoint import (latest_step, restore_checkpoint,
                                      restore_param_history,
                                      save_checkpoint, save_param_delta)
from repro.storage.kv import LogFileKV, MemKV, PartitionedKV


def test_columnar_roundtrip():
    rng = np.random.default_rng(0)
    arrays = {"a": rng.integers(0, 100, 17).astype(np.int32),
              "b": rng.standard_normal((3, 5)).astype(np.float32),
              "c": np.zeros(0, np.int16)}
    blob = col.pack_arrays(arrays)
    out = col.unpack_arrays(blob)
    for k in arrays:
        assert np.array_equal(out[k], arrays[k])
        assert out[k].dtype == arrays[k].dtype


def test_delta_codec_roundtrip():
    rng = np.random.default_rng(1)
    d = Delta(rng.integers(0, 50, 5).astype(np.int32),
              rng.integers(0, 50, 3).astype(np.int32),
              rng.integers(0, 90, 7).astype(np.int32),
              np.zeros(0, np.int32),
              AttrDelta(np.array([1, 2], np.int32), np.array([0, 1], np.int16),
                        np.array([1.5, 2.5], np.float32),
                        np.array([np.nan, 0.5], np.float32)),
              AttrDelta.empty())
    parts = col.encode_delta(d)
    d2 = col.decode_delta(parts)
    assert np.array_equal(d2.node_add, d.node_add)
    assert np.array_equal(d2.edge_add, d.edge_add)
    assert np.array_equal(d2.node_attr.new, d.node_attr.new)
    assert np.array_equal(d2.node_attr.old, d.node_attr.old, equal_nan=True)


@pytest.mark.parametrize("make", [MemKV, None])
def test_kv_backends(tmp_path, make):
    kv = make() if make else LogFileKV(str(tmp_path / "kv"))
    kv.put((0, 1, "struct"), b"hello")
    kv.put((2, 7, "nodeattr.3"), b"world" * 100)
    assert kv.get((0, 1, "struct")) == b"hello"
    assert (2, 7, "nodeattr.3") in kv
    assert (9, 9, "x") not in kv
    assert set(kv.keys()) == {(0, 1, "struct"), (2, 7, "nodeattr.3")}
    kv.put((0, 1, "struct"), b"hello2")  # overwrite
    assert kv.get((0, 1, "struct")) == b"hello2"
    assert kv.stats.puts == 3
    kv.close()


def test_logfile_kv_reopen_and_torn_tail(tmp_path):
    path = str(tmp_path / "kv")
    kv = LogFileKV(path)
    kv.put((0, 1, "a"), b"x" * 100)
    kv.flush()
    kv.put((0, 2, "b"), b"y" * 100)   # not flushed into the index
    kv._fh.flush()
    kv._fh.close()
    # simulate a crash with a torn tail record
    with open(os.path.join(path, "kv.log"), "ab") as f:
        f.write(b"RKV1\x05\x00\x00\x00abc")  # truncated record
    kv2 = LogFileKV(path)
    assert kv2.get((0, 1, "a")) == b"x" * 100
    assert kv2.get((0, 2, "b")) == b"y" * 100  # recovered unflushed record
    assert (0, 3, "c") not in kv2
    kv2.close()


def test_partitioned_kv(tmp_path):
    kv = PartitionedKV([MemKV(), MemKV(), MemKV()])
    for p in range(3):
        kv.put((p, 0, "struct"), bytes([p]))
    assert all(kv.get((p, 0, "struct")) == bytes([p]) for p in range(3))
    assert len(kv.parts[1].keys()) == 1


def test_checkpoint_roundtrip(tmp_path):
    store = LogFileKV(str(tmp_path / "ckpt"))
    tree = {"w": jnp.arange(12, dtype=jnp.float32).reshape(3, 4),
            "nested": {"b": jnp.ones(5, jnp.bfloat16)},
            "step": jnp.int32(7)}
    save_checkpoint(store, 100, tree, extra={"data_cursor": 12345},
                    n_shards=2)
    assert latest_step(store) == 100
    got, extra, step = restore_checkpoint(store, like=tree)
    assert step == 100 and extra["data_cursor"] == 12345
    assert np.array_equal(np.asarray(got["w"]), np.arange(12).reshape(3, 4))
    assert got["nested"]["b"].dtype == jnp.bfloat16


def test_checkpoint_crash_keeps_previous(tmp_path):
    store = LogFileKV(str(tmp_path / "ckpt"))
    tree = {"w": jnp.zeros(4)}
    save_checkpoint(store, 1, tree)
    # a partial later checkpoint without manifest/latest commit
    store.put((0, 2, "ckpt/w/0"), b"garbage-partial")
    got, _, step = restore_checkpoint(store, like=tree)
    assert step == 1


def test_param_delta_history(tmp_path):
    store = MemKV()
    t0 = {"w": np.arange(10, dtype=np.float32)}
    t1 = {"w": t0["w"].copy()}
    t1["w"][3] = 99.0
    t2 = {"w": t1["w"].copy()}
    t2["w"][7] = -1.0
    save_param_delta(store, 0, None, t0)
    b1 = save_param_delta(store, 1, 0, t1, t0)
    b2 = save_param_delta(store, 2, 1, t2, t1)
    full = save_param_delta(MemKV(), 0, None, t2)
    assert b1 < 200 and b2 < 200  # sparse deltas are tiny
    hist = restore_param_history(store, [0, 1, 2], like=t0)
    assert hist[1]["w"][3] == 99.0 and hist[1]["w"][7] == 7.0
    assert hist[2]["w"][7] == -1.0


# ---------------------------------------------------------------------------
# LogFileKV compaction
# ---------------------------------------------------------------------------

def _fill(kv, n=20, size=200):
    for i in range(n):
        kv.put((0, i, "c"), bytes([i % 251]) * size)


def test_logfile_compact_reclaims_dead_bytes(tmp_path):
    kv = LogFileKV(str(tmp_path / "kv"), auto_compact=False)
    _fill(kv)
    for i in range(10):                      # overwrite half
        kv.put((0, i, "c"), b"new-%d" % i)
    for i in range(15, 20):                  # delete a few
        kv.delete((0, i, "c"))
    assert kv.dead_bytes > 0
    size_before = os.path.getsize(kv.log_path)
    res = kv.compact()
    assert res["reclaimed_bytes"] > 0
    assert os.path.getsize(kv.log_path) < size_before
    assert kv.dead_bytes == 0 and kv.compactions == 1
    for i in range(10):
        assert kv.get((0, i, "c")) == b"new-%d" % i
    for i in range(10, 15):
        assert kv.get((0, i, "c")) == bytes([i % 251]) * 200
    for i in range(15, 20):
        assert (0, i, "c") not in kv
    kv.close()
    # reopens cleanly from the compacted log + rewritten index
    kv2 = LogFileKV(str(tmp_path / "kv"))
    assert kv2.get((0, 3, "c")) == b"new-3"
    kv2.close()


def test_logfile_auto_compact_triggers(tmp_path):
    kv = LogFileKV(str(tmp_path / "kv"), compact_min_bytes=2_000,
                   compact_ratio=0.4)
    for round_ in range(30):
        for i in range(8):
            kv.put((0, i, "c"), bytes([round_]) * 120)
    assert kv.compactions > 0
    assert kv.dead_ratio() < 0.5
    for i in range(8):
        assert kv.get((0, i, "c")) == bytes([29]) * 120
    kv.close()


def test_logfile_compact_crash_before_log_swap(tmp_path, monkeypatch):
    """Killed after rewriting the live set but before the os.replace
    commit point: the old log + index are untouched and the stray
    ``.compact`` file is discarded on reopen."""
    path = str(tmp_path / "kv")
    kv = LogFileKV(path, auto_compact=False)
    _fill(kv)
    for i in range(10):
        kv.put((0, i, "c"), b"v2-%d" % i)
    kv.flush()

    real_replace = os.replace

    def crash_on_log_swap(src, dst):
        if src.endswith(".compact"):
            raise RuntimeError("simulated crash before log swap")
        return real_replace(src, dst)

    monkeypatch.setattr(os, "replace", crash_on_log_swap)
    with pytest.raises(RuntimeError):
        kv.compact()
    monkeypatch.undo()
    assert os.path.exists(kv.log_path + ".compact")  # the orphaned rewrite
    kv2 = LogFileKV(path)                            # "reboot"
    assert not os.path.exists(kv2.log_path + ".compact")
    for i in range(10):
        assert kv2.get((0, i, "c")) == b"v2-%d" % i
    for i in range(10, 20):
        assert kv2.get((0, i, "c")) == bytes([i % 251]) * 200
    kv2.close()


def test_logfile_compact_crash_before_index_rewrite(tmp_path):
    """Killed after the log swap but before the fresh index write: the
    old index was invalidated *before* the commit point, so recovery
    full-scans the compacted log — exact even when the crash hit with
    unflushed puts and deletes outstanding (the stale-index scenario
    that would otherwise read wrong bytes at old offsets)."""
    path = str(tmp_path / "kv")
    kv = LogFileKV(path, auto_compact=False)
    _fill(kv, n=4)
    kv.flush()                      # index snapshot of the *early* log
    _fill(kv)                       # lots of unflushed churn afterwards
    for i in range(12):
        kv.put((0, i, "c"), b"live-%d" % i)
    kv.delete((0, 18, "c"))
    kv.delete((0, 19, "c"))

    def crash(*a, **k):
        raise RuntimeError("simulated crash before index rewrite")

    kv._write_index_locked = crash              # instance-level hook
    with pytest.raises(RuntimeError):
        kv.compact()
    assert not os.path.exists(kv.index_path)    # invalidated pre-commit
    kv2 = LogFileKV(path)
    for i in range(12):
        assert kv2.get((0, i, "c")) == b"live-%d" % i
    for i in range(12, 18):
        assert kv2.get((0, i, "c")) == bytes([i % 251]) * 200
    for i in (18, 19):
        assert (0, i, "c") not in kv2           # deletes do not resurrect
    kv2.close()


def test_logfile_delete_tombstones_survive_index_loss(tmp_path):
    """A full-scan rebuild (index lost) must not resurrect deleted keys:
    deletes append tombstone records to the log."""
    path = str(tmp_path / "kv")
    kv = LogFileKV(path, auto_compact=False)
    _fill(kv, n=6)
    kv.delete((0, 2, "c"))
    kv.delete((0, 4, "c"))
    kv._fh.flush()
    kv._fh.close()                  # crash: index.json never written
    os.path.exists(kv.index_path) and os.remove(kv.index_path)
    kv2 = LogFileKV(path)
    assert (0, 2, "c") not in kv2 and (0, 4, "c") not in kv2
    for i in (0, 1, 3, 5):
        assert kv2.get((0, i, "c")) == bytes([i % 251]) * 200
    kv2.close()


# ---------------------------------------------------------------------------
# TieredKV
# ---------------------------------------------------------------------------

def test_tiered_kv_basic(tmp_path):
    from repro.storage.kv import TieredKV
    cold = MemKV()
    kv = TieredKV(cold, hot_bytes=1000, max_item_frac=1.0)
    kv.put((0, 1, "a"), b"x" * 400)
    kv.put((0, 2, "b"), b"y" * 400)
    assert kv.get((0, 1, "a")) == b"x" * 400       # hot hit
    assert kv.stats.hot_hits == 1 and kv.stats.hot_misses == 0
    kv.put((0, 3, "c"), b"z" * 400)                 # evicts LRU (key 2)
    assert kv.evictions >= 1
    assert kv.hot_bytes_used() <= 1000
    assert kv.get((0, 2, "b")) == b"y" * 400       # cold miss, re-admitted
    assert kv.stats.hot_misses == 1
    assert kv.stats.gets == kv.stats.hot_hits + kv.stats.hot_misses
    # overwrite is visible immediately, never the stale blob
    kv.put((0, 1, "a"), b"new")
    assert kv.get((0, 1, "a")) == b"new"
    kv.delete((0, 1, "a"))
    assert (0, 1, "a") not in kv
    with pytest.raises(KeyError):
        kv.get((0, 1, "a"))
    assert set(kv.keys()) == {(0, 2, "b"), (0, 3, "c")}
    assert kv.total_bytes() == cold.total_bytes()


def test_tiered_kv_oversized_items_bypass_hot(tmp_path):
    from repro.storage.kv import TieredKV
    kv = TieredKV(MemKV(), hot_bytes=1000, max_item_frac=0.25)
    kv.put((0, 1, "big"), b"B" * 900)               # > 250 B cap: not admitted
    assert kv.hot_bytes_used() == 0
    assert kv.get((0, 1, "big")) == b"B" * 900      # served from cold
    assert kv.stats.hot_misses == 1


def test_tiered_kv_over_logfile_persists(tmp_path):
    from repro.storage.kv import TieredKV
    d = str(tmp_path / "cold")
    kv = TieredKV(LogFileKV(d), hot_bytes=1 << 20)
    kv.put((0, 1, "a"), b"payload-1")
    kv.put((1, 2, "b"), b"payload-2")
    kv.flush()
    kv.close()
    kv2 = TieredKV(LogFileKV(d), hot_bytes=1 << 20)
    assert kv2.get((0, 1, "a")) == b"payload-1"     # cold miss from disk
    assert kv2.stats.hot_misses == 1
    assert kv2.get((0, 1, "a")) == b"payload-1"     # now hot
    assert kv2.stats.hot_hits == 1
    kv2.close()


def test_tiered_kv_resize_hot(tmp_path):
    from repro.storage.kv import TieredKV
    kv = TieredKV(MemKV(), hot_bytes=1 << 20, max_item_frac=1.0)
    for i in range(10):
        kv.put((0, i, "c"), bytes(100))
    assert kv.hot_bytes_used() == 1000
    kv.resize_hot(350)
    assert kv.hot_bytes_used() <= 350


def test_tiered_kv_invalidate_fences_inflight_cold_reads():
    """A cold read that straddles ``invalidate_hot()`` must not admit its
    (possibly pre-publish) bytes.  This is the shardd scenario: the cache
    is read-only (writes happen at the origin), so per-key versions never
    move and only the generation fence keeps a blob fetched *before* an
    epoch invalidation from re-entering the hot tier *after* it — where a
    newer-epoch reader would trust it."""
    from repro.storage.kv import TieredKV
    cold = MemKV()
    key = (0, 1, "a")
    cold.put(key, b"old")
    kv = TieredKV(cold, hot_bytes=1 << 20, max_item_frac=1.0)

    orig_mget = cold.mget

    def racy_mget(keys):
        out = orig_mget(keys)        # reads the pre-publish bytes
        cold.put(key, b"new")        # origin overwritten by the commit
        kv.invalidate_hot()          # announce lands before admission
        return out

    cold.mget = racy_mget
    try:
        # the in-flight reader still gets the old bytes (its epoch pin
        # predates the publish) ...
        assert kv.mget([key]) == [b"old"]
    finally:
        cold.mget = orig_mget
    # ... but they were never admitted, so a post-publish reader reads
    # through to the fresh origin bytes
    assert kv.hot_bytes_used() == 0
    assert kv.get(key) == b"new"

    kv.invalidate_hot()              # clear before testing the get() path
    orig_get = cold.get

    def racy_get(k):
        v = orig_get(k)
        cold.put(key, b"newer")
        kv.invalidate_hot()
        return v

    cold.get = racy_get
    try:
        assert kv.get(key) == b"new"
    finally:
        cold.get = orig_get
    assert kv.hot_bytes_used() == 0
    assert kv.get(key) == b"newer"
