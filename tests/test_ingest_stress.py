"""Writer/reader concurrency stress for the ingest pipeline.

One writer thread streams pre-generated events through the threaded
:class:`IngestPipeline` (small ``L`` so leaf rollovers and red/green
skeleton swaps fire continuously) while N reader threads issue
``Q.at`` / ``Q.between`` documents.  Every result must be **bit-identical**
to a replay oracle evaluated at the reader's pinned epoch — the
``epoch_events`` stat names the exact group-aligned event prefix the
query was answered against, so the oracle is ``replay(uni, ev[:ne], t)``.
"""
from __future__ import annotations

import threading
import time

import numpy as np

from repro.core.events import (EV_NEW_EDGE, EV_NEW_NODE, EV_TRANS_EDGE,
                               EV_TRANS_NODE, replay)
from repro.core.ingest import IngestPipeline
from repro.core.manager import GraphManager
from repro.api.document import Q
from repro.data.generators import random_history

N_BUILD = 100
N_TOTAL = 1200
L = 48
N_READERS = 4
ATTRS = "+node:all+edge:all"


def _interval_oracle(ev, ne: int, ts: int, te: int) -> dict:
    sub = ev[:ne]
    m = (sub.time >= ts) & (sub.time < te)
    tr = m & np.isin(sub.etype, (EV_TRANS_EDGE, EV_TRANS_NODE))
    return {
        "node_added": np.unique(
            sub.slot[m & (sub.etype == EV_NEW_NODE)]).astype(np.int32),
        "edge_added": np.unique(
            sub.slot[m & (sub.etype == EV_NEW_EDGE)]).astype(np.int32),
        "transient": sorted(zip(sub.time[tr].tolist(),
                                sub.slot[tr].tolist())),
    }


def _check_state(got, want, tag) -> str | None:
    if not (np.array_equal(got.node_mask, want.node_mask)
            and np.array_equal(got.edge_mask, want.edge_mask)):
        return f"{tag}: mask mismatch"
    if not (np.allclose(got.node_attrs, want.node_attrs, equal_nan=True)
            and np.allclose(got.edge_attrs, want.edge_attrs,
                            equal_nan=True)):
        return f"{tag}: attr mismatch"
    return None


def test_readers_see_consistent_epochs_during_ingest():
    uni, ev = random_history(N_TOTAL, 41)
    gm = GraphManager(uni, ev[:N_BUILD], L=L, k=2)
    pipe = IngestPipeline(gm, group_events=32, group_window_s=0.002,
                          threaded=True)
    gm._ingest = pipe
    svc = gm.query
    tmax = int(ev.time.max()) + 2

    errors: list[str] = []
    checks = [0] * N_READERS
    stop = threading.Event()

    def point_reader(idx: int) -> None:
        rng = np.random.default_rng(100 + idx)
        while not stop.is_set():
            ts = sorted({int(t) for t in rng.integers(0, tmax, size=3)})
            r = svc.run(Q.at(ts).attrs(ATTRS).build() if len(ts) > 1
                        else Q.at(ts[0]).attrs(ATTRS).build())
            ne = r.stats["epoch_events"]
            states = r.value if isinstance(r.value, dict) else {ts[0]: r.value}
            for t, got in states.items():
                err = _check_state(got, replay(uni, ev[:ne], int(t)),
                                   f"point t={t} ne={ne}")
                if err:
                    errors.append(err)
            checks[idx] += 1

    def interval_reader(idx: int) -> None:
        rng = np.random.default_rng(200 + idx)
        while not stop.is_set():
            a, b = sorted(int(t) for t in rng.integers(0, tmax, size=2))
            r = svc.run(Q.between(a, b + 1).build())
            ne = r.stats["epoch_events"]
            want = _interval_oracle(ev, ne, a, b + 1)
            got = r.value
            if not (np.array_equal(got["node_added"], want["node_added"])
                    and np.array_equal(got["edge_added"],
                                       want["edge_added"])):
                errors.append(f"interval [{a},{b + 1}) ne={ne}: adds")
            got_tr = sorted(zip(got["transient_time"].tolist(),
                                got["transient_slot"].tolist()))
            if got_tr != want["transient"]:
                errors.append(f"interval [{a},{b + 1}) ne={ne}: transients")
            checks[idx] += 1

    readers = ([threading.Thread(target=point_reader, args=(i,))
                for i in range(N_READERS // 2)]
               + [threading.Thread(target=interval_reader, args=(i,))
                  for i in range(N_READERS // 2, N_READERS)])
    for r in readers:
        r.start()
    try:
        rng = np.random.default_rng(0)
        i = N_BUILD
        while i < N_TOTAL:
            j = min(N_TOTAL, i + int(rng.integers(5, 40)))
            pipe.submit(ev[i:j])
            i = j
            time.sleep(0.001)       # let readers interleave with commits
        pipe.drain(timeout=60)
    finally:
        stop.set()
        for r in readers:
            r.join(timeout=30)

    assert not errors, errors[:10]
    assert all(c > 0 for c in checks), checks
    assert pipe.rollovers > 0, "stress run never exercised a rollover"
    # every reader pin released; every superseded epoch reclaimed
    est = gm.epochs.stats()
    assert est["current_refs"] == 0 and est["retired_pending"] == 0, est
    # final state identical to a crash-free offline build
    final = svc.run(Q.at(tmax - 1).attrs(ATTRS).build())
    assert final.stats["epoch_events"] == N_TOTAL
    err = _check_state(final.value, replay(uni, ev, tmax - 1), "final")
    assert err is None, err
    gm.close()


def test_forced_rollover_storm_with_batches():
    """Tiny leaves + explicit rollover calls racing a batch reader:
    grouped ``run_batch`` documents must share one pinned epoch."""
    uni, ev = random_history(700, 43)
    gm = GraphManager(uni, ev[:N_BUILD], L=24, k=2)
    pipe = IngestPipeline(gm, group_events=16, threaded=False)
    gm._ingest = pipe
    svc = gm.query
    tmax = int(ev.time.max()) + 2

    errors: list[str] = []
    stop = threading.Event()

    def reader() -> None:
        rng = np.random.default_rng(7)
        while not stop.is_set():
            ts = sorted({int(t) for t in rng.integers(0, tmax, size=4)})
            docs = [Q.at(t).attrs(ATTRS).build() for t in ts]
            results = svc.run_batch(docs)
            # all grouped docs report the same epoch
            eids = {r.stats["epoch"] for r in results}
            if len(eids) != 1:
                errors.append(f"batch spanned epochs {eids}")
            for t, r in zip(ts, results):
                ne = r.stats["epoch_events"]
                err = _check_state(r.value, replay(uni, ev[:ne], t),
                                   f"batch t={t} ne={ne}")
                if err:
                    errors.append(err)

    th = threading.Thread(target=reader)
    th.start()
    try:
        rng = np.random.default_rng(1)
        i = N_BUILD
        while i < 700:
            j = min(700, i + int(rng.integers(3, 30)))
            pipe.append(ev[i:j])
            i = j
    finally:
        stop.set()
        th.join(timeout=30)

    assert not errors, errors[:10]
    assert pipe.rollovers >= 5
    gm.close()


def test_socket_sessions_pin_epochs_during_ingest():
    """Satellite of the serving tentpole: N NDJSON *socket* sessions
    query through the concurrent server while the ingest pipeline commits
    groups and forces rollovers.  Every envelope must be bit-identical
    (mask + attr CRCs) to the replay oracle at its own pinned
    ``epoch_events`` prefix, and must answer its session's request
    (correlation id) in order."""
    import json
    import socket

    from repro.api.service import _crc
    from repro.launch.server import QueryServer

    uni, ev = random_history(N_TOTAL, 47)
    gm = GraphManager(uni, ev[:N_BUILD], L=L, k=2)
    pipe = IngestPipeline(gm, group_events=32, group_window_s=0.002,
                          threaded=True)
    gm._ingest = pipe
    tmax = int(ev.time.max()) + 2
    srv = QueryServer(gm, window_ms=2.0, workers=3).start()

    n_sessions = 4
    errors: list[str] = []
    checks = [0] * n_sessions
    stop = threading.Event()

    def session(idx: int) -> None:
        rng = np.random.default_rng(300 + idx)
        sock = socket.create_connection((srv.host, srv.port))
        f = sock.makefile("rw", encoding="utf-8", newline="\n")
        try:
            while not stop.is_set():
                t = int(rng.integers(0, tmax))
                rid = f"s{idx}-{checks[idx]}"
                f.write(json.dumps({"kind": "snapshot", "t": t,
                                    "attrs": ATTRS, "id": rid}) + "\n")
                f.flush()
                env = json.loads(f.readline())
                if not env.get("ok"):
                    errors.append(f"{rid}: {env.get('error')}")
                    break
                if env.get("id") != rid:
                    errors.append(f"{rid}: cross-wired to {env.get('id')}")
                    break
                ne = env["stats"]["epoch_events"]
                want = replay(uni, ev[:ne], t)
                got = env["result"]
                if (got["nodes"], got["edges"]) != \
                        (int(want.node_mask.sum()),
                         int(want.edge_mask.sum())):
                    errors.append(f"{rid} ne={ne}: counts mismatch")
                elif (got["node_crc"] != _crc(np.packbits(want.node_mask))
                      or got["edge_crc"]
                      != _crc(np.packbits(want.edge_mask))):
                    errors.append(f"{rid} ne={ne}: mask crc mismatch")
                elif got["attr_crc"] != (_crc(want.node_attrs)
                                         ^ _crc(want.edge_attrs)):
                    errors.append(f"{rid} ne={ne}: attr crc mismatch")
                checks[idx] += 1
        finally:
            f.close()
            sock.close()

    sessions = [threading.Thread(target=session, args=(i,))
                for i in range(n_sessions)]
    for s in sessions:
        s.start()
    try:
        rng = np.random.default_rng(2)
        i = N_BUILD
        while i < N_TOTAL:
            j = min(N_TOTAL, i + int(rng.integers(5, 40)))
            pipe.submit(ev[i:j])
            i = j
            time.sleep(0.001)
        pipe.drain(timeout=60)
    finally:
        stop.set()
        for s in sessions:
            s.join(timeout=30)
        srv.close()

    assert not errors, errors[:10]
    assert all(c > 0 for c in checks), checks
    assert pipe.rollovers > 0, "never exercised a rollover under serving"
    # every session pin released once the server is down
    est = gm.epochs.stats()
    assert est["current_refs"] == 0 and est["retired_pending"] == 0, est
    gm.close()
