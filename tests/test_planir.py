"""Unified retrieval-plan IR + batched execution engine.

Covers: IR structure (typed steps, legacy surface, fork insertion, fetch
dedup), shared-prefix merging, the host executor with and without async
KV prefetch, the vmapped JAX DAG backend vs the oracle, the batch
scheduler, the manager-level ``get_snapshots`` batch API, the
advisor-evict → snapshot-cache invalidation, and the aggregated
PartitionedKV stats.
"""
import numpy as np
import pytest

from conftest import assert_state_equal
from repro.core import GraphManager, replay
from repro.core.planir import (ApplyDelta, ApplyElist, Fetch, Fork,
                               Materialize, Source, merge_irs)
from repro.core.query import NO_ATTRS, parse_attr_options
from repro.data.generators import churn_network
from repro.runtime.executor import (BatchScheduler, Prefetcher,
                                    RetrievalRequest)

RNG = np.random.default_rng(13)


@pytest.fixture(scope="module")
def setup():
    uni, ev = churn_network(n_initial_edges=150, n_events=1200, seed=11)
    gm = GraphManager(uni, ev, L=80, k=2)
    return uni, ev, gm


# ---------------------------------------------------------------------------
# IR structure
# ---------------------------------------------------------------------------


def test_singlepoint_ir_shape(setup):
    uni, ev, gm = setup
    t = int(ev.time[600])
    ir = gm.dg.plan_singlepoint(t, NO_ATTRS)
    ops = [type(n.op) for n in ir.nodes]
    assert ops.count(Source) == 1
    assert ops.count(Materialize) == 1
    # legacy surface: linear steps, source first, actions as tuples
    steps = ir.steps
    assert steps[0].parent is None
    assert steps[0].action[0] in ("empty", "mat", "current")
    for a, b in zip(steps, steps[1:]):
        assert b.parent == a.key
    # total weight is the Dijkstra distance (sum of step weights)
    assert ir.total_weight == pytest.approx(sum(s.weight for s in steps))


def test_fetch_nodes_deduped_per_payload(setup):
    """Chained multipoint targets share a leaf-eventlist: the IR must carry
    ONE Fetch node per payload however many partial applies consume it."""
    uni, ev, gm = setup
    t0 = int(ev.time[500])
    ir = gm.dg.plan_multipoint([t0, t0 + 1, t0 + 2], NO_ATTRS)
    fetches = [n.op for n in ir.nodes if isinstance(n.op, Fetch)]
    assert len(fetches) == len(set(fetches))
    assert ir.payload_fetches == len(fetches)
    # and at least one eventlist payload is consumed by >= 2 applies
    elist_uses = {}
    for n in ir.nodes:
        if isinstance(n.op, ApplyElist):
            elist_uses[n.op.pid] = elist_uses.get(n.op.pid, 0) + 1
    assert max(elist_uses.values()) >= 2


def test_multipoint_ir_has_forks(setup):
    uni, ev, gm = setup
    times = [int(t) for t in np.linspace(ev.time[10], ev.time[-10], 8)]
    ir = gm.dg.plan_multipoint(times, NO_ATTRS)
    forks = [n for n in ir.nodes if isinstance(n.op, Fork)]
    assert forks, "8 spread-out targets must share a trunk and fork"
    byid = {n.nid: n for n in ir.nodes}
    for f in forks:
        assert f.op.fanout >= 2
        # fork consumers reference the fork, which passes its parent's key
        parent = byid[f.deps[0]]
        assert f.key == parent.key


def test_merge_irs_shared_prefix(setup):
    """Merging per-query singlepoint plans dedups the shared skeleton
    prefix: merged weight < sum of individual weights, and every shared
    payload is fetched once."""
    uni, ev, gm = setup
    times = [int(t) for t in np.linspace(ev.time[10], ev.time[-10], 16)]
    irs = [gm.dg.plan_singlepoint(t, NO_ATTRS) for t in times]
    merged = merge_irs(irs)
    indiv = sum(ir.total_weight for ir in irs)
    assert merged.total_weight < indiv
    assert set(merged.targets) == set(times)
    fetch_keys = [(n.op.kind, n.op.pid) for n in merged.nodes
                  if isinstance(n.op, Fetch)]
    assert len(fetch_keys) == len(set(fetch_keys))


# ---------------------------------------------------------------------------
# executors
# ---------------------------------------------------------------------------


def test_host_executor_with_prefetch_matches_oracle(setup):
    uni, ev, gm = setup
    opts = parse_attr_options("+node:all+edge:all", uni)
    times = [int(t) for t in RNG.integers(0, int(ev.time[-1]) + 2, 10)]
    with Prefetcher(gm.store, workers=4) as pf:
        got = gm.dg.get_snapshots(times, opts, pool=gm.pool, prefetch=pf)
    for t in set(times):
        assert_state_equal(got[t], replay(uni, ev, t), msg=f"t={t}")


def test_jax_dag_executor_matches_oracle(setup):
    from repro.runtime.jax_exec import execute_multipoint_jax
    uni, ev, gm = setup
    times = [int(t) for t in RNG.integers(0, int(ev.time[-1]) + 2, 12)]
    masks = execute_multipoint_jax(gm.dg, times, pool=gm.pool)
    for t in set(times):
        truth = replay(uni, ev, t)
        nm, em = masks[t]
        assert np.array_equal(nm, truth.node_mask), t
        assert np.array_equal(em, truth.edge_mask), t


def test_jax_executor_lands_in_pool(setup):
    from repro.runtime.jax_exec import execute_multipoint_jax
    uni, ev, gm = setup
    times = [int(ev.time[i]) for i in (100, 500, 900)]
    gids = execute_multipoint_jax(gm.dg, times, pool=gm.pool,
                                  land_in_pool=True)
    for t, gid in gids.items():
        truth = replay(uni, ev, t)
        assert np.array_equal(gm.pool.get_node_mask(gid), truth.node_mask)
        assert np.array_equal(gm.pool.get_edge_mask(gid), truth.edge_mask)
        gm.pool.release(gid)
    gm.pool.cleaner(force=True)


def test_batch_scheduler_dedups_and_matches(setup):
    uni, ev, gm = setup
    times = [int(t) for t in np.linspace(ev.time[20], ev.time[-20], 16)]
    sched = BatchScheduler(gm.dg, pool=gm.pool)
    results = sched.run([RetrievalRequest([t]) for t in times])
    assert sched.last_merged.total_weight < sched.last_individual_weight
    for res, t in zip(results, times):
        truth = replay(uni, ev, t)
        assert np.array_equal(res[t].node_mask, truth.node_mask)


def test_manager_get_snapshots_batch_and_cache(setup):
    uni, ev, gm = setup
    gm.cache.clear()
    times = [int(ev.time[i]) for i in (50, 450, 850, 1150)]
    out = gm.get_snapshots(times, "+node:all")
    hits_before = gm.workload.cache_hits
    out2 = gm.get_snapshots(times, "+node:all")   # exact repeat → all hits
    assert gm.workload.cache_hits >= hits_before + len(times)
    for t in times:
        truth = replay(uni, ev, t)
        assert np.array_equal(out[t].node_mask, truth.node_mask)
        assert out[t].equal(out2[t])


# ---------------------------------------------------------------------------
# satellite: advisor eviction invalidates routed-through cache entries
# ---------------------------------------------------------------------------


def test_advisor_evict_drops_dependent_cache_entries():
    uni, ev = churn_network(n_initial_edges=120, n_events=1000, seed=23)
    gm = GraphManager(uni, ev, L=64, k=2)
    gm.enable_advisor(budget_bytes=8 << 20, replan_every=10_000)
    pinned = set(gm.advisor.pinned)
    assert pinned, "advisor should pin something under an 8 MiB budget"
    # issue queries; some plans route through the pins
    tmax = int(ev.time[-1])
    for t in range(0, tmax, max(tmax // 20, 1)):
        gm.get_snapshot(int(t))
    # a query at the newest time plans from the current graph — no pin deps
    gm.get_snapshot(tmax)
    dep_keys = [k for k, d in gm.cache._deps.items() if d & pinned]
    safe_keys = [k for k in gm.cache._d if k not in gm.cache._deps]
    assert dep_keys, "some cached entries must have routed through a pin"
    assert safe_keys, "the current-sourced entry must carry no pin deps"
    gm.disable_advisor()
    for k in dep_keys:
        assert k not in gm.cache._d, "stale entry survived pin eviction"
    for k in safe_keys:
        assert k in gm.cache._d, "untouched entry must survive"


def test_workload_records_node_hits():
    uni, ev = churn_network(n_initial_edges=100, n_events=600, seed=29)
    gm = GraphManager(uni, ev, L=64, k=2, cache_bytes=0)
    gm.get_snapshot(int(ev.time[300]))
    hits = gm.workload.node_hits
    assert hits and all(n in gm.dg.nodes for n in hits)


# ---------------------------------------------------------------------------
# satellite: PartitionedKV aggregated stats
# ---------------------------------------------------------------------------


def test_partitioned_kv_stats_aggregate():
    from repro.storage.kv import MemKV, PartitionedKV
    parts = [MemKV() for _ in range(4)]
    kv = PartitionedKV(parts)
    for p in range(4):
        kv.put((p, 0, "struct"), b"x" * (10 * (p + 1)))
    assert kv.stats.puts == 4
    assert kv.stats.bytes_written == 10 + 20 + 30 + 40
    for p in range(4):
        kv.get((p, 0, "struct"))
    # a direct backend read must still be visible in the aggregate
    parts[0].get((0, 0, "struct"))
    assert kv.stats.gets == 5
    assert kv.stats.bytes_read == 100 + 10
    kv.stats.reset()
    assert kv.stats.gets == 0 and kv.stats.bytes_written == 0


def test_kv_stats_thread_safe_under_prefetch():
    from concurrent.futures import ThreadPoolExecutor
    from repro.storage.kv import MemKV
    kv = MemKV()
    kv.put((0, 0, "c"), b"abc")
    N = 2000
    with ThreadPoolExecutor(8) as ex:
        list(ex.map(lambda _: kv.get((0, 0, "c")), range(N)))
    assert kv.stats.gets == N + 0
    assert kv.stats.bytes_read == 3 * N


def test_jax_executor_after_universe_growth():
    """Live updates that add new slots (§6) grow the universe past older
    states; source bitmaps must be re-fit to the live word count."""
    from repro.core.events import GraphHistoryBuilder
    from repro.runtime.jax_exec import execute_multipoint_jax
    b = GraphHistoryBuilder()
    for i in range(8):
        b.add_node(i, t=i)
    for i in range(7):
        b.add_edge(i, i + 1, t=10 + i, edge_id=("e", i))
    uni, ev = b.finalize()
    gm = GraphManager(uni, ev, L=4, k=2)
    upd = GraphHistoryBuilder()
    upd.universe = uni
    upd._seq = 10_000
    for i in range(40):                  # grow well past a 32-bit word
        upd.add_node(("new", i), 100 + i)
    _, ev2 = upd.finalize()
    gm.update(ev2)
    from repro.core.events import EventList
    all_ev = EventList.concat([ev, ev2])
    masks = execute_multipoint_jax(gm.dg, [12, 105, 139], pool=gm.pool)
    for t, (nm, em) in masks.items():
        truth = replay(uni, all_ev, t)
        assert np.array_equal(nm, truth.node_mask), t
        assert np.array_equal(em, truth.edge_mask), t
