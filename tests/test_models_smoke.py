"""Assigned-architecture smoke tests (assignment requirement):
reduced config of the same family, one forward/train step on CPU,
asserting output shapes + no NaNs.  Plus prefill/decode consistency for
the LM family and learning checks for GNN/recsys."""
import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.configs.registry import ARCH_IDS, family_of, reduced_config
from repro.models import common as mc
from repro.models.gnn import gnn_forward, gnn_loss, gnn_param_defs
from repro.models.recsys.din import (din_forward, din_loss, din_param_defs,
                                     din_retrieval)
from repro.models.transformer import model as tm
from repro.training.optim import OPTIMIZERS
from repro.training.trainer import make_train_step

pytestmark = pytest.mark.slow  # reduced-config model steps still take seconds

RNG = np.random.default_rng(0)
KEY = jax.random.PRNGKey(0)

LM = [a for a in ARCH_IDS if family_of(a) == "lm"]
GNN = [a for a in ARCH_IDS if family_of(a) == "gnn"]


def rand_graph(N, E):
    src = RNG.integers(0, N, E // 2).astype(np.int32)
    dst = RNG.integers(0, N, E // 2).astype(np.int32)
    return jnp.array(np.stack([np.concatenate([src, dst]),
                               np.concatenate([dst, src])]))


@pytest.mark.parametrize("arch", LM)
def test_lm_smoke(arch):
    cfg = reduced_config(arch)
    params = mc.init_params(tm.param_defs(cfg), KEY)
    B, S = 2, 16
    tokens = jnp.array(RNG.integers(0, cfg.vocab, (B, S)), jnp.int32)
    opt_name = "adafactor" if cfg.moe else "adamw"
    opt = OPTIMIZERS[opt_name](lr=1e-3)
    state = opt[0](params)
    step = jax.jit(make_train_step(lambda p, b: tm.loss_fn(p, b, cfg), opt))
    p2, s2, m = step(params, state, {"tokens": tokens})
    assert np.isfinite(float(m["loss"]))
    logits, _, _, _ = jax.jit(lambda p, t: tm.forward(p, t, cfg))(params, tokens)
    assert logits.shape == (B, S, cfg.vocab)
    assert np.all(np.isfinite(np.asarray(logits, np.float32)))


@pytest.mark.parametrize("arch", LM)
def test_lm_prefill_decode_consistency(arch):
    cfg = reduced_config(arch)
    params = mc.init_params(tm.param_defs(cfg), KEY)
    B, S = 2, 12
    tokens = jnp.array(RNG.integers(0, cfg.vocab, (B, S)), jnp.int32)
    last, _ = jax.jit(lambda p, t: tm.prefill_step(p, t, cfg))(params, tokens)
    cache = tm.init_cache(cfg, B, S + 2)
    dec = jax.jit(lambda p, c, t, l: tm.decode_step(p, c, t, l, cfg))
    lg = None
    for i in range(S):
        lg, cache = dec(params, cache, tokens[:, i:i + 1], jnp.int32(i))
    a, b = np.asarray(lg, np.float32), np.asarray(last, np.float32)
    err = np.abs(a - b).max() / (np.abs(b).max() + 1e-6)
    assert err < 5e-2, err


def test_grad_accumulation_consistency():
    """accum_steps=2 ≈ full-batch step (bf16 tolerance)."""
    cfg = reduced_config("yi-34b")
    params = mc.init_params(tm.param_defs(cfg), KEY)
    tokens = jnp.array(RNG.integers(0, cfg.vocab, (4, 8)), jnp.int32)
    opt = OPTIMIZERS["sgd"](lr=1e-2, momentum=0.0)
    state = opt[0](params)
    s1 = jax.jit(make_train_step(lambda p, b: tm.loss_fn(p, b, cfg), opt))
    s2 = jax.jit(make_train_step(lambda p, b: tm.loss_fn(p, b, cfg), opt,
                                 accum_steps=2))
    p1, _, _ = s1(params, state, {"tokens": tokens})
    p2, _, _ = s2(params, state, {"tokens": tokens})
    d1 = np.asarray(p1["final_norm"], np.float32)
    d2 = np.asarray(p2["final_norm"], np.float32)
    np.testing.assert_allclose(d1, d2, rtol=0.1, atol=1e-2)


@pytest.mark.parametrize("arch", GNN)
def test_gnn_smoke(arch):
    cfg = reduced_config(arch)
    N, E = 48, 160
    ei = rand_graph(N, E)
    if cfg.kind in ("gcn", "gin"):
        batch = {"x": jnp.asarray(RNG.standard_normal((N, cfg.d_in)), jnp.float32),
                 "edge_index": ei,
                 "labels": jnp.asarray(RNG.integers(0, cfg.n_classes, N), jnp.int32),
                 "label_mask": jnp.ones(N, jnp.float32)}
        out_shape = (N, cfg.n_classes)
    elif cfg.kind == "meshgraphnet":
        batch = {"x": jnp.asarray(RNG.standard_normal((N, cfg.d_node_in)), jnp.float32),
                 "edge_attr": jnp.asarray(RNG.standard_normal((E, cfg.d_edge_in)), jnp.float32),
                 "edge_index": ei,
                 "target": jnp.asarray(RNG.standard_normal((N, cfg.d_out)), jnp.float32)}
        out_shape = (N, cfg.d_out)
    else:
        T = 4 * E
        batch = {"z": jnp.asarray(RNG.integers(1, 10, N), jnp.int32),
                 "pos": jnp.asarray(RNG.standard_normal((N, 3)), jnp.float32),
                 "edge_index": ei,
                 "triplet_kj": jnp.asarray(RNG.integers(0, E, T), jnp.int32),
                 "triplet_ji": jnp.asarray(RNG.integers(0, E, T), jnp.int32),
                 "graph_ids": jnp.zeros(N, jnp.int32),
                 "target": jnp.asarray(RNG.standard_normal((1, cfg.d_out)), jnp.float32)}
        out_shape = (1, cfg.d_out)
    params = mc.init_params(gnn_param_defs(cfg), KEY)
    out = jax.jit(lambda p, b: gnn_forward(p, b, cfg))(params, batch)
    assert out.shape == out_shape
    assert np.all(np.isfinite(np.asarray(out)))
    lr = 3e-4 if cfg.kind == "dimenet" else 1e-3  # dimenet energies start huge
    opt = OPTIMIZERS["adamw"](lr=lr)
    state = opt[0](params)
    step = jax.jit(make_train_step(lambda p, b: gnn_loss(p, b, cfg), opt))
    losses = []
    for _ in range(8):
        params, state, m = step(params, state, batch)
        losses.append(float(m["loss"]))
    assert all(np.isfinite(l) for l in losses)
    assert min(losses[1:]) < losses[0]


def test_din_smoke():
    cfg = reduced_config("din")
    params = mc.init_params(din_param_defs(cfg), KEY)
    B, S = 16, cfg.seq_len
    batch = {"hist_goods": jnp.asarray(RNG.integers(0, cfg.n_goods, (B, S)), jnp.int32),
             "hist_cates": jnp.asarray(RNG.integers(0, cfg.n_cates, (B, S)), jnp.int32),
             "hist_mask": jnp.asarray(RNG.random((B, S)) < 0.8),
             "target_goods": jnp.asarray(RNG.integers(0, cfg.n_goods, B), jnp.int32),
             "target_cates": jnp.asarray(RNG.integers(0, cfg.n_cates, B), jnp.int32),
             "labels": jnp.asarray(RNG.integers(0, 2, B), jnp.int32)}
    logit = jax.jit(lambda p, b: din_forward(p, b, cfg))(params, batch)
    assert logit.shape == (B,) and np.all(np.isfinite(np.asarray(logit)))
    opt = OPTIMIZERS["adamw"](lr=1e-2)
    state = opt[0](params)
    step = jax.jit(make_train_step(lambda p, b: din_loss(p, b, cfg), opt))
    losses = []
    for _ in range(6):
        params, state, m = step(params, state, batch)
        losses.append(float(m["loss"]))
    assert losses[-1] < losses[0]
    rb = {k: v for k, v in batch.items() if k.startswith("hist")}
    rb["cand_goods"] = jnp.asarray(RNG.integers(0, cfg.n_goods, (B, 64)), jnp.int32)
    rb["cand_cates"] = jnp.asarray(RNG.integers(0, cfg.n_cates, (B, 64)), jnp.int32)
    scores = jax.jit(lambda p, b: din_retrieval(p, b, cfg))(params, rb)
    assert scores.shape == (B, 64)


def test_embedding_bag_matches_manual():
    from repro.models.recsys.embedding import embedding_bag
    table = jnp.asarray(RNG.standard_normal((20, 4)), jnp.float32)
    idx = jnp.asarray([[1, 3, -1], [0, -1, -1]], jnp.int32)
    out = embedding_bag(table, idx, mode="sum")
    exp0 = np.asarray(table)[1] + np.asarray(table)[3]
    np.testing.assert_allclose(np.asarray(out[0]), exp0, rtol=1e-6)
    out_m = embedding_bag(table, idx, mode="mean")
    np.testing.assert_allclose(np.asarray(out_m[0]), exp0 / 2, rtol=1e-6)
    # ragged form
    flat = jnp.asarray([1, 3, 0], jnp.int32)
    offs = jnp.asarray([0, 2], jnp.int32)
    out_r = embedding_bag(table, flat, offs, mode="sum")
    np.testing.assert_allclose(np.asarray(out_r[0]), exp0, rtol=1e-6)
