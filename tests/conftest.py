import os
import sys

# NOTE: no XLA_FLAGS here on purpose — smoke tests and benches must see the
# real single-device CPU; only launch/dryrun.py (and the subprocesses in
# test_distributed.py) fake 512/8 devices.
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import numpy as np  # noqa: E402
import pytest  # noqa: E402


@pytest.fixture(scope="session")
def churn():
    from repro.data.generators import churn_network
    return churn_network(n_initial_edges=150, n_events=1200, seed=1)


@pytest.fixture(scope="session")
def growing():
    from repro.data.generators import growing_network
    return growing_network(n_events=1500, seed=2)


def assert_state_equal(got, truth, check_attrs=True, msg=""):
    assert np.array_equal(got.node_mask, truth.node_mask), f"node mask {msg}"
    assert np.array_equal(got.edge_mask, truth.edge_mask), f"edge mask {msg}"
    if check_attrs:
        assert truth.equal(got), f"attrs {msg}"
