"""Property-based tests (hypothesis) on the system's invariants:

* any DeltaGraph configuration retrieves exactly the oracle snapshot at
  any time point (the paper's core claim);
* delta algebra: apply∘diff = identity, inverse roundtrip;
* bitmap pack/unpack/indices roundtrips;
* multipoint ≡ singlepoint;
* epoch lifecycle: random acquire/publish/release interleavings never
  reclaim a referenced epoch, never serve a torn read, and drain to
  zero refs.
"""
import numpy as np
import pytest

hypothesis = pytest.importorskip(
    "hypothesis", reason="optional dep: property tests need hypothesis")
from hypothesis import given, settings, strategies as st  # noqa: E402

from repro.core import GraphManager, replay
from repro.core import bitmaps as bm
from repro.core.deltas import apply_delta, state_diff
from repro.core.query import parse_attr_options
from repro.data.generators import random_history

cfg_strategy = st.fixed_dictionaries({
    "n_events": st.integers(40, 300),
    "seed": st.integers(0, 10_000),
    "L": st.sampled_from([16, 32, 64]),
    "k": st.sampled_from([2, 3, 4]),
    "diff": st.sampled_from(["balanced", "intersection", "union", "empty",
                             "mixed"]),
    "P": st.sampled_from([1, 3]),
})


@settings(max_examples=15, deadline=None)
@given(cfg=cfg_strategy, qseed=st.integers(0, 999))
def test_retrieval_matches_oracle(cfg, qseed):
    uni, ev = random_history(cfg["n_events"], cfg["seed"])
    params = dict(r1=0.7, r2=0.2) if cfg["diff"] == "mixed" else {}
    gm = GraphManager(uni, ev, L=cfg["L"], k=cfg["k"], diff_fn=cfg["diff"],
                      diff_params=params, num_partitions=cfg["P"])
    opts = parse_attr_options("+node:all+edge:all", uni)
    rng = np.random.default_rng(qseed)
    tmax = int(ev.time[-1]) if len(ev) else 0
    times = [int(t) for t in rng.integers(-2, tmax + 3, 4)]
    for t in times:
        truth = replay(uni, ev, t)
        got = gm.dg.get_snapshot(t, opts, pool=gm.pool)
        assert np.array_equal(got.node_mask, truth.node_mask), (cfg, t)
        assert np.array_equal(got.edge_mask, truth.edge_mask), (cfg, t)
        assert truth.equal(got), (cfg, t, "attrs")
    # multipoint plan returns identical states
    multi = gm.dg.get_snapshots(times, opts, pool=gm.pool)
    for t in times:
        truth = replay(uni, ev, t)
        assert truth.equal(multi[t]), (cfg, t, "multipoint")


@settings(max_examples=20, deadline=None)
@given(n=st.integers(20, 200), s1=st.integers(0, 99), s2=st.integers(0, 99))
def test_delta_laws(n, s1, s2):
    uni, ev = random_history(n, s1)
    rng = np.random.default_rng(s2)
    tmax = int(ev.time[-1]) if len(ev) else 0
    t1, t2 = sorted(int(t) for t in rng.integers(0, tmax + 1, 2))
    a, b = replay(uni, ev, t1), replay(uni, ev, t2)
    d = state_diff(b, a)
    fwd = apply_delta(a, d)
    assert np.array_equal(fwd.node_mask, b.node_mask)
    assert np.array_equal(fwd.edge_mask, b.edge_mask)
    assert b.equal(fwd)
    back = apply_delta(b, d, forward=False)
    assert np.array_equal(back.node_mask, a.node_mask)
    assert a.equal(back)


@settings(max_examples=30, deadline=None)
@given(u=st.integers(1, 300), seed=st.integers(0, 9999))
def test_bitmap_roundtrip(u, seed):
    rng = np.random.default_rng(seed)
    mask = rng.random(u) < 0.4
    words = bm.np_pack(mask)
    assert np.array_equal(bm.np_unpack(words, u), mask)
    idx = np.nonzero(mask)[0]
    assert np.array_equal(bm.np_from_indices(idx, u), words)
    assert bm.np_popcount(words) == mask.sum()
    # jnp variants agree
    import jax.numpy as jnp
    assert np.array_equal(np.asarray(bm.pack(jnp.asarray(mask))), words)
    assert np.array_equal(np.asarray(bm.unpack(jnp.asarray(words), u)), mask)
    assert np.array_equal(
        np.asarray(bm.from_indices(jnp.asarray(idx, jnp.int32), u)), words)


@settings(max_examples=10, deadline=None)
@given(n=st.integers(30, 150), seed=st.integers(0, 999),
       cut=st.floats(0.1, 0.9))
def test_incremental_append_equivalence(n, seed, cut):
    """Index built in one shot ≡ built half-then-appended."""
    uni, ev = random_history(n, seed)
    k = int(len(ev) * cut)
    gm = GraphManager(uni, ev[:k], L=24, k=2)
    for i in range(k, len(ev), 11):
        gm.update(ev[i:i + 11])
    opts = parse_attr_options("+node:all+edge:all", uni)
    rng = np.random.default_rng(seed)
    tmax = int(ev.time[-1])
    for t in [int(x) for x in rng.integers(0, tmax + 2, 3)]:
        truth = replay(uni, ev, t)
        got = gm.dg.get_snapshot(t, opts, pool=gm.pool)
        assert truth.equal(got), t


# epoch lifecycle: ops are (kind, arg) drawn from a small alphabet and
# interpreted against a model; pins are addressed by the index of the
# acquire op that created them, so shrinking stays meaningful.
_epoch_ops = st.lists(
    st.one_of(
        st.just(("acquire", 0)),
        st.builds(lambda i: ("release", i), st.integers(0, 40)),
        st.just(("publish", 0)),
    ),
    min_size=1, max_size=60)


@settings(max_examples=60, deadline=None)
@given(ops=_epoch_ops)
def test_epoch_lifecycle_invariants(ops):
    from repro.core.epoch import EpochData, EpochRegistry

    reclaimed: list[int] = []
    reg = EpochRegistry(EpochData(dg="v0", n_events=0))
    pins: list = []           # all acquired pins, released or not
    live: list = []           # indices into pins still holding a ref
    last_seen_id = -1
    version_of: dict[int, str] = {0: "v0"}

    for kind, arg in ops:
        if kind == "acquire":
            pin = reg.acquire()
            # never a torn read: the pinned data is exactly what was
            # published under that id
            assert pin.data.dg == version_of[pin.id]
            # monotonic: never handed an id older than one already seen
            assert pin.id >= last_seen_id
            last_seen_id = pin.id
            live.append(len(pins))
            pins.append(pin)
        elif kind == "release" and live:
            idx = live.pop(arg % len(live))
            pins[idx].release()
            pins[idx].release()        # idempotent
        elif kind == "publish":
            nid = reg.current_id + 1
            version_of[nid] = f"v{nid}"
            old = reg.current_id
            reg.publish(EpochData(dg=version_of[nid], n_events=nid),
                        reclaims=[lambda e=old: reclaimed.append(e)])
            assert reg.current_id == nid
        # a reclaimed epoch must have no live pin on it or anything older
        if reclaimed:
            newest_reclaimed = max(reclaimed)
            for idx in live:
                assert pins[idx].id > newest_reclaimed, \
                    "reclaimed an epoch a live pin could still reach"
        # reclaims run in publish order, exactly once
        assert reclaimed == sorted(reclaimed)
        assert len(reclaimed) == len(set(reclaimed))

    for idx in live:
        pins[idx].release()
    st_ = reg.stats()
    assert st_["current_refs"] == 0
    assert st_["retired_pending"] == 0
    # after every pin drains, every superseded epoch was reclaimed
    assert reclaimed == list(range(reg.current_id))
