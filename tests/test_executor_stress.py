"""Concurrency stress for the retrieval stack.

16 threads drive overlapping ``get_snapshots`` batches (and
``BatchScheduler`` runs) against one shared GraphManager — shared
snapshot cache, shared prefetch pool, shared KV store.  Assertions:

* no deadlock (every thread joins within the timeout) and no worker
  exceptions;
* every returned state equals the brute-force oracle (the cache never
  serves a torn or aliased entry);
* ``KVStats`` counters are exactly consistent with an independently
  locked count of the physical gets (unlocked ``+=`` would drop
  increments under this contention);
* snapshot-cache dependency tracking: after advisor evictions, no
  surviving cache entry references an evicted pin.

Advisor *replans* mutate the GraphPool and are serialized by
``GraphManager._advisor_lock``; in-flight plans that already resolved a
pin are not protected (documented in ARCHITECTURE.md "Concurrency"), so
the eviction-invalidation assertions run in the quiesced phase.
"""
from __future__ import annotations

import threading

import numpy as np

from repro.core import GraphManager, replay
from repro.core.query import NO_ATTRS
from repro.data.generators import churn_network
from repro.runtime.executor import BatchScheduler, RetrievalRequest
from repro.storage.kv import MemKV

N_THREADS = 16
BATCHES_PER_THREAD = 6
JOIN_TIMEOUT_S = 120.0


class CountingKV(MemKV):
    """MemKV plus an independently-locked physical-get counter to
    difference against the built-in (also locked) ``KVStats``."""

    def __init__(self) -> None:
        super().__init__()
        self._count_lock = threading.Lock()
        self.physical_gets = 0

    def get(self, key):
        with self._count_lock:
            self.physical_gets += 1
        return super().get(key)


def _fixture():
    uni, ev = churn_network(n_initial_edges=120, n_events=1500, seed=21)
    store = CountingKV()
    gm = GraphManager(uni, ev, store=store, L=64, k=2, prefetch_workers=4)
    tmax = int(ev.time[-1])
    rng = np.random.default_rng(5)
    distinct = sorted({int(t) for t in rng.integers(0, tmax + 1, 40)})
    truth = {t: replay(uni, ev, t) for t in distinct}
    return uni, ev, store, gm, distinct, truth, rng


def _run_threads(workers):
    threads = [threading.Thread(target=w, daemon=True) for w in workers]
    for th in threads:
        th.start()
    for th in threads:
        th.join(timeout=JOIN_TIMEOUT_S)
    assert not any(th.is_alive() for th in threads), \
        "deadlock: worker threads did not finish"


def test_concurrent_get_snapshots_stress():
    uni, ev, store, gm, distinct, truth, rng = _fixture()
    errors: list = []
    barrier = threading.Barrier(N_THREADS)
    batches = [[list(rng.choice(distinct, size=6))
                for _ in range(BATCHES_PER_THREAD)]
               for _ in range(N_THREADS)]

    def worker(i):
        try:
            barrier.wait(timeout=JOIN_TIMEOUT_S)
            for batch in batches[i]:
                out = gm.get_snapshots(batch)
                for t in batch:
                    st = out[int(t)]
                    tr = truth[int(t)]
                    assert np.array_equal(st.node_mask, tr.node_mask), t
                    assert np.array_equal(st.edge_mask, tr.edge_mask), t
        except Exception as e:  # noqa: BLE001 - surfaced via main thread
            errors.append((i, repr(e)))

    _run_threads([lambda i=i: worker(i) for i in range(N_THREADS)])
    assert errors == []
    # KVStats counters must not have dropped increments under contention
    assert store.stats.gets == store.physical_gets
    assert gm.cache is not None and len(gm.cache) <= gm.cache.max_entries
    # every (deduped) query was either a cache hit or recorded in the
    # histogram — no increment may be lost under contention
    wl = gm.workload
    expected = sum(len({int(t) for t in b}) for tb in batches for b in tb)
    assert wl.num_queries + wl.cache_hits == expected
    gm.close()


def test_concurrent_batch_scheduler_stress():
    uni, ev, store, gm, distinct, truth, rng = _fixture()
    errors: list = []
    barrier = threading.Barrier(N_THREADS)

    def worker(i):
        try:
            barrier.wait(timeout=JOIN_TIMEOUT_S)
            sched = BatchScheduler(gm.dg, pool=gm.pool,
                                   prefetcher=gm.prefetcher)
            reqs = [RetrievalRequest(times=list(
                rng.choice(distinct, size=3))) for _ in range(3)]
            for res, req in zip(sched.run(reqs, NO_ATTRS), reqs):
                for t in req.times:
                    tr = truth[int(t)]
                    assert np.array_equal(res[int(t)].node_mask,
                                          tr.node_mask), t
                    assert np.array_equal(res[int(t)].edge_mask,
                                          tr.edge_mask), t
        except Exception as e:  # noqa: BLE001
            errors.append((i, repr(e)))

    _run_threads([lambda i=i: worker(i) for i in range(N_THREADS)])
    assert errors == []
    assert store.stats.gets == store.physical_gets
    gm.close()


def test_cache_deps_invalidated_on_advisor_evict():
    """Entries whose plans routed through an advisor pin are dropped when
    the pin is evicted — surviving deps may only reference live pins."""
    uni, ev, store, gm, distinct, truth, rng = _fixture()
    gm.enable_advisor(budget_bytes=2 << 20, replan_every=10**9)
    for t in distinct:
        st = gm.get_snapshot(t)
        assert np.array_equal(st.node_mask, truth[t].node_mask), t
    pinned_before = set(gm.advisor.pinned)
    assert pinned_before, "advisor must have pinned something"
    # some cached entries should record pin dependencies
    deps_before = gm.cache.dep_keys()
    assert any(d & pinned_before for d in deps_before.values())

    # shrinking the budget to ~zero evicts every pin -> dependent entries go
    gm.advisor.replan(budget_bytes=1)
    live_pins = set(gm.advisor.pinned)
    evicted = pinned_before - live_pins
    assert evicted
    for key, deps in gm.cache.dep_keys().items():
        assert not (deps & evicted), (key, deps & evicted)
    # hits after the purge still serve oracle-exact states
    for t in distinct[:10]:
        st = gm.get_snapshot(t)
        assert np.array_equal(st.node_mask, truth[t].node_mask), t
        assert np.array_equal(st.edge_mask, truth[t].edge_mask), t
    gm.disable_advisor()
    # with the advisor fully off, no entry may reference any former pin
    for key, deps in gm.cache.dep_keys().items():
        assert not (deps & pinned_before), key
    gm.close()
