"""Concurrency stress for the retrieval stack.

16 threads drive overlapping ``get_snapshots`` batches (and
``BatchScheduler`` runs) against one shared GraphManager — shared
snapshot cache, shared prefetch pool, shared KV store.  Assertions:

* no deadlock (every thread joins within the timeout) and no worker
  exceptions;
* every returned state equals the brute-force oracle (the cache never
  serves a torn or aliased entry);
* ``KVStats`` counters are exactly consistent with an independently
  locked count of the physical gets (unlocked ``+=`` would drop
  increments under this contention);
* snapshot-cache dependency tracking: after advisor evictions, no
  surviving cache entry references an evicted pin.

Advisor *replans* mutate the GraphPool and are serialized by
``GraphManager._advisor_lock``; in-flight plans that already resolved a
pin are not protected (documented in ARCHITECTURE.md "Concurrency"), so
the eviction-invalidation assertions run in the quiesced phase.
"""
from __future__ import annotations

import threading

import numpy as np

from repro.core import GraphManager, replay
from repro.core.query import NO_ATTRS
from repro.data.generators import churn_network
from repro.runtime.executor import BatchScheduler, RetrievalRequest
from repro.storage.kv import MemKV

N_THREADS = 16
BATCHES_PER_THREAD = 6
JOIN_TIMEOUT_S = 120.0


class CountingKV(MemKV):
    """MemKV plus an independently-locked physical-get counter to
    difference against the built-in (also locked) ``KVStats``."""

    def __init__(self) -> None:
        super().__init__()
        self._count_lock = threading.Lock()
        self.physical_gets = 0

    def get(self, key):
        with self._count_lock:
            self.physical_gets += 1
        return super().get(key)


def _fixture():
    uni, ev = churn_network(n_initial_edges=120, n_events=1500, seed=21)
    store = CountingKV()
    gm = GraphManager(uni, ev, store=store, L=64, k=2, prefetch_workers=4)
    tmax = int(ev.time[-1])
    rng = np.random.default_rng(5)
    distinct = sorted({int(t) for t in rng.integers(0, tmax + 1, 40)})
    truth = {t: replay(uni, ev, t) for t in distinct}
    return uni, ev, store, gm, distinct, truth, rng


def _run_threads(workers):
    threads = [threading.Thread(target=w, daemon=True) for w in workers]
    for th in threads:
        th.start()
    for th in threads:
        th.join(timeout=JOIN_TIMEOUT_S)
    assert not any(th.is_alive() for th in threads), \
        "deadlock: worker threads did not finish"


def test_concurrent_get_snapshots_stress():
    uni, ev, store, gm, distinct, truth, rng = _fixture()
    errors: list = []
    barrier = threading.Barrier(N_THREADS)
    batches = [[list(rng.choice(distinct, size=6))
                for _ in range(BATCHES_PER_THREAD)]
               for _ in range(N_THREADS)]

    def worker(i):
        try:
            barrier.wait(timeout=JOIN_TIMEOUT_S)
            for batch in batches[i]:
                out = gm.get_snapshots(batch)
                for t in batch:
                    st = out[int(t)]
                    tr = truth[int(t)]
                    assert np.array_equal(st.node_mask, tr.node_mask), t
                    assert np.array_equal(st.edge_mask, tr.edge_mask), t
        except Exception as e:  # noqa: BLE001 - surfaced via main thread
            errors.append((i, repr(e)))

    _run_threads([lambda i=i: worker(i) for i in range(N_THREADS)])
    assert errors == []
    # KVStats counters must not have dropped increments under contention
    assert store.stats.gets == store.physical_gets
    assert gm.cache is not None and len(gm.cache) <= gm.cache.max_entries
    # every (deduped) query was either a cache hit or recorded in the
    # histogram — no increment may be lost under contention
    wl = gm.workload
    expected = sum(len({int(t) for t in b}) for tb in batches for b in tb)
    assert wl.num_queries + wl.cache_hits == expected
    gm.close()


def test_concurrent_batch_scheduler_stress():
    uni, ev, store, gm, distinct, truth, rng = _fixture()
    errors: list = []
    barrier = threading.Barrier(N_THREADS)

    def worker(i):
        try:
            barrier.wait(timeout=JOIN_TIMEOUT_S)
            sched = BatchScheduler(gm.dg, pool=gm.pool,
                                   prefetcher=gm.prefetcher)
            reqs = [RetrievalRequest(times=list(
                rng.choice(distinct, size=3))) for _ in range(3)]
            for res, req in zip(sched.run(reqs, NO_ATTRS), reqs):
                for t in req.times:
                    tr = truth[int(t)]
                    assert np.array_equal(res[int(t)].node_mask,
                                          tr.node_mask), t
                    assert np.array_equal(res[int(t)].edge_mask,
                                          tr.edge_mask), t
        except Exception as e:  # noqa: BLE001
            errors.append((i, repr(e)))

    _run_threads([lambda i=i: worker(i) for i in range(N_THREADS)])
    assert errors == []
    assert store.stats.gets == store.physical_gets
    gm.close()


def test_cache_deps_invalidated_on_advisor_evict():
    """Entries whose plans routed through an advisor pin are dropped when
    the pin is evicted — surviving deps may only reference live pins."""
    uni, ev, store, gm, distinct, truth, rng = _fixture()
    gm.enable_advisor(budget_bytes=2 << 20, replan_every=10**9)
    for t in distinct:
        st = gm.get_snapshot(t)
        assert np.array_equal(st.node_mask, truth[t].node_mask), t
    pinned_before = set(gm.advisor.pinned)
    assert pinned_before, "advisor must have pinned something"
    # some cached entries should record pin dependencies
    deps_before = gm.cache.dep_keys()
    assert any(d & pinned_before for d in deps_before.values())

    # shrinking the budget to ~zero evicts every pin -> dependent entries go
    gm.advisor.replan(budget_bytes=1)
    live_pins = set(gm.advisor.pinned)
    evicted = pinned_before - live_pins
    assert evicted
    for key, deps in gm.cache.dep_keys().items():
        assert not (deps & evicted), (key, deps & evicted)
    # hits after the purge still serve oracle-exact states
    for t in distinct[:10]:
        st = gm.get_snapshot(t)
        assert np.array_equal(st.node_mask, truth[t].node_mask), t
        assert np.array_equal(st.edge_mask, truth[t].edge_mask), t
    gm.disable_advisor()
    # with the advisor fully off, no entry may reference any former pin
    for key, deps in gm.cache.dep_keys().items():
        assert not (deps & pinned_before), key
    gm.close()


# ---------------------------------------------------------------------------
# TieredKV under contention
# ---------------------------------------------------------------------------

def test_tiered_kv_overwrite_stress():
    """16 threads hammer get/put/evict on a tiny hot tier: no get may ever
    return a version older than the last put *it* could observe, and the
    ``gets == hot_hits + hot_misses`` stats invariant holds exactly."""
    import struct as _struct

    from repro.storage.kv import MemKV, TieredKV

    cold = MemKV()
    kv = TieredKV(cold, hot_bytes=2048, max_item_frac=1.0)
    KEYS = [(0, i, "blob") for i in range(8)]
    committed = {k: 0 for k in KEYS}      # last version whose put returned
    commit_lock = threading.Lock()
    get_count = [0]
    count_lock = threading.Lock()
    errors: list = []
    stop = threading.Event()
    barrier = threading.Barrier(N_THREADS)

    def encode(ver: int) -> bytes:
        return _struct.pack("<Q", ver) + bytes(100)

    def writer(i):
        try:
            barrier.wait(timeout=JOIN_TIMEOUT_S)
            for ver in range(1, 120):
                k = KEYS[(i + ver) % len(KEYS)]
                # serialize writers per run so "committed" is meaningful
                with commit_lock:
                    nxt = committed[k] + 1
                    kv.put(k, encode(nxt))
                    committed[k] = nxt
        except Exception as e:  # noqa: BLE001
            errors.append(("w", i, repr(e)))
        finally:
            stop.set()

    def reader(i):
        try:
            barrier.wait(timeout=JOIN_TIMEOUT_S)
            while not stop.is_set():
                k = KEYS[i % len(KEYS)]
                with commit_lock:
                    floor = committed[k]
                v = kv.get(k) if floor else None
                with count_lock:
                    get_count[0] += 1 if v is not None else 0
                if v is not None:
                    (ver,) = _struct.unpack_from("<Q", v)
                    assert ver >= floor, (k, ver, floor)
        except Exception as e:  # noqa: BLE001
            errors.append(("r", i, repr(e)))

    workers = [lambda i=i: writer(i) for i in range(4)]
    workers += [lambda i=i: reader(i) for i in range(N_THREADS - 4)]
    _run_threads(workers)
    assert errors == []
    st = kv.stats
    assert st.gets == st.hot_hits + st.hot_misses
    assert st.gets == get_count[0]
    assert kv.hot_bytes_used() <= kv.hot_bytes
    # after all puts returned, every key serves its final committed version
    for k in KEYS:
        (ver,) = _struct.unpack_from("<Q", kv.get(k))
        assert ver == committed[k], k


def test_tiered_retrieval_stress():
    """The full 16-thread batched-retrieval stress against a TieredKV whose
    hot tier is far smaller than the store: results stay oracle-exact and
    both tiers' counters stay consistent."""
    from repro.storage.kv import TieredKV

    uni, ev = churn_network(n_initial_edges=120, n_events=1500, seed=21)
    cold = CountingKV()
    store = TieredKV(cold, hot_bytes=16 << 10, max_item_frac=1.0)
    gm = GraphManager(uni, ev, store=store, L=64, k=2, prefetch_workers=4)
    tmax = int(ev.time[-1])
    rng = np.random.default_rng(5)
    distinct = sorted({int(t) for t in rng.integers(0, tmax + 1, 40)})
    truth = {t: replay(uni, ev, t) for t in distinct}
    errors: list = []
    barrier = threading.Barrier(N_THREADS)
    batches = [[list(rng.choice(distinct, size=6))
                for _ in range(BATCHES_PER_THREAD)]
               for _ in range(N_THREADS)]

    def worker(i):
        try:
            barrier.wait(timeout=JOIN_TIMEOUT_S)
            for batch in batches[i]:
                out = gm.get_snapshots(batch)
                for t in batch:
                    st = out[int(t)]
                    tr = truth[int(t)]
                    assert np.array_equal(st.node_mask, tr.node_mask), t
                    assert np.array_equal(st.edge_mask, tr.edge_mask), t
        except Exception as e:  # noqa: BLE001
            errors.append((i, repr(e)))

    _run_threads([lambda i=i: worker(i) for i in range(N_THREADS)])
    assert errors == []
    # logical gets tag exactly one tier each; the cold backend's physical
    # counter agrees with its own locked count
    assert store.stats.gets == store.stats.hot_hits + store.stats.hot_misses
    assert cold.stats.gets == cold.physical_gets
    # every hot miss went to the cold tier at least once (retries allowed)
    assert cold.stats.gets >= store.stats.hot_misses
    assert store.hot_bytes_used() <= store.hot_bytes
    gm.close()
