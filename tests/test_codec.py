"""Payload codec (storage/codec.py): stage round-trips, typed errors on
corrupt input, the version-gated raw fallback, and the decoded-payload
cache.  Deterministic coverage mirrors the hypothesis properties so the
same edges are pinned even where hypothesis is not installed."""
import numpy as np
import pytest

from repro.storage import codec
from repro.storage.codec import (CodecError, blob_info, bitpack, bitunpack,
                                 decode_blob, encode_blob, varint_decode,
                                 varint_encode)

INT_DTYPES = [np.int8, np.int16, np.int32, np.int64,
              np.uint8, np.uint16, np.uint32, np.uint64]
ALL_DTYPES = INT_DTYPES + [np.float32, np.float64, np.bool_]


def _assert_roundtrip(arrays: dict, codec_name: str = "v2") -> bytes:
    blob = encode_blob(arrays, codec=codec_name)
    out = decode_blob(blob)
    assert set(out) == set(arrays)
    for k, a in arrays.items():
        assert out[k].dtype == a.dtype, k
        assert out[k].shape == a.shape, k
        assert np.array_equal(out[k], a, equal_nan=a.dtype.kind == "f"), k
    return blob


# ---------------------------------------------------------------------------
# stage primitives
# ---------------------------------------------------------------------------

def test_varint_roundtrip():
    rng = np.random.default_rng(0)
    for vals in (np.zeros(0, np.uint64),
                 np.array([0, 1, 127, 128, 2**14 - 1, 2**14], np.uint64),
                 np.array([2**63, 2**64 - 1, 0], np.uint64),
                 rng.integers(0, 2**63, 500, dtype=np.uint64)):
        assert np.array_equal(varint_decode(varint_encode(vals), vals.size),
                              vals)


def test_varint_malformed():
    with pytest.raises(CodecError):
        varint_decode(b"\x80\x80", 1)          # no terminator
    with pytest.raises(CodecError):
        varint_decode(b"\x01\x01", 1)          # wrong count
    with pytest.raises(CodecError):
        varint_decode(b"\x80" * 10 + b"\x01", 1)  # > 64 bits
    with pytest.raises(CodecError):
        varint_decode(b"\x01", 0)              # trailing bytes


def test_bitpack_roundtrip():
    rng = np.random.default_rng(1)
    for width in (1, 3, 7, 8, 13, 32):
        vals = rng.integers(0, 2**width, 300, dtype=np.uint64)
        assert np.array_equal(bitunpack(bitpack(vals, width), 300, width),
                              vals)
    assert bitunpack(b"", 0, 5).size == 0
    with pytest.raises(CodecError):
        bitunpack(b"\x01", 100, 13)            # stream too short


# ---------------------------------------------------------------------------
# blob round-trips
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("dt", ALL_DTYPES)
@pytest.mark.parametrize("codec_name", ["v2", "raw"])
def test_roundtrip_dtypes(dt, codec_name):
    rng = np.random.default_rng(42)
    dt = np.dtype(dt)
    if dt.kind == "f":
        a = rng.standard_normal(137).astype(dt)
    elif dt.kind == "b":
        a = rng.random(137) < 0.5
    else:
        info = np.iinfo(dt)
        a = rng.integers(info.min, int(info.max) + 1, 137,
                         dtype=np.int64 if dt.kind == "i" else np.uint64
                         ).astype(dt)
    _assert_roundtrip({"a": a, "sorted": np.sort(a.ravel())}, codec_name)


def test_roundtrip_edge_shapes():
    _assert_roundtrip({
        "empty_i64": np.zeros(0, np.int64),
        "empty_f32": np.zeros(0, np.float32),
        "matrix": np.arange(35, dtype=np.int32).reshape(5, 7),
        "single": np.array([7], np.int16),
        "nan_inf": np.array([np.nan, np.inf, -np.inf, 0.0], np.float32),
    })


def test_roundtrip_extreme_values():
    i64 = np.iinfo(np.int64)
    _assert_roundtrip({
        "extremes": np.array([i64.min, i64.max, 0, -1, 1] * 5, np.int64),
        "u64_top": np.array([0, 2**64 - 1, 2**63, 12345] * 5, np.uint64),
        "alternating": np.array([i64.min, i64.max] * 20, np.int64),
    })


def test_sorted_columns_compress():
    """The delta-of-delta/varint stages earn their keep on the shapes the
    system actually stores: sorted time/pos columns, small-range codes."""
    rng = np.random.default_rng(3)
    n = 2000
    arrays = {"pos": np.arange(n, dtype=np.int32),
              "time": np.sort(rng.integers(0, 10**9, n)).astype(np.int64),
              "etype": rng.integers(0, 8, n).astype(np.int16),
              "slot": rng.integers(0, 50000, n).astype(np.int32)}
    blob = _assert_roundtrip(arrays)
    logical = sum(a.nbytes for a in arrays.values())
    assert len(blob) * 3 <= logical, (len(blob), logical)
    info = blob_info(blob)
    assert info["codec"] == "v2" and info["logical_bytes"] == logical
    assert info["stored_bytes"] == len(blob)


# ---------------------------------------------------------------------------
# typed errors — corruption never decodes into garbage arrays
# ---------------------------------------------------------------------------

def _sample_blob() -> bytes:
    rng = np.random.default_rng(5)
    return encode_blob({"time": np.sort(rng.integers(0, 10**6, 400)),
                        "slot": rng.integers(0, 1000, 400).astype(np.int32)})


@pytest.mark.parametrize("cut", [0, 1, 3, 4, 10, 19])
def test_truncated_header_raises(cut):
    with pytest.raises(CodecError):
        decode_blob(_sample_blob()[:cut])


def test_truncated_body_raises():
    blob = _sample_blob()
    for cut in (len(blob) // 2, len(blob) - 1):
        with pytest.raises(CodecError):
            decode_blob(blob[:cut])


def test_corrupt_body_raises():
    blob = bytearray(_sample_blob())
    blob[25] ^= 0xFF
    with pytest.raises(CodecError):
        decode_blob(bytes(blob))


def test_unknown_version_raises():
    blob = bytearray(_sample_blob())
    blob[4] = 99                     # version byte
    with pytest.raises(CodecError):
        decode_blob(bytes(blob))


def test_legacy_garbage_raises():
    with pytest.raises(CodecError):
        decode_blob(b"\x02\x00\x00\x00garbage-that-is-not-a-bundle")
    with pytest.raises(CodecError):
        decode_blob(b"")


def test_unknown_codec_name():
    with pytest.raises(CodecError):
        encode_blob({"a": np.zeros(3)}, codec="zstd-nope")
    with pytest.raises(CodecError):
        codec.set_default_codec("nope")


# ---------------------------------------------------------------------------
# version-gated fallback: pre-codec blobs keep decoding
# ---------------------------------------------------------------------------

def test_legacy_raw_blob_decodes():
    rng = np.random.default_rng(6)
    arrays = {"a": rng.integers(0, 100, 50).astype(np.int32),
              "b": rng.standard_normal((3, 5)).astype(np.float32)}
    legacy = codec._pack_raw(arrays)          # the pre-codec wire format
    out = decode_blob(legacy)
    for k in arrays:
        assert np.array_equal(out[k], arrays[k])
        assert out[k].dtype == arrays[k].dtype
    assert blob_info(legacy)["codec"] == "raw"


def test_mixed_store_raw_then_v2():
    """An index built entirely under the raw codec keeps serving after the
    default flips to v2, and appends written as v2 interleave with the old
    raw blobs in one store — the migration story is 'none needed'."""
    from repro.core import GraphManager, replay
    from repro.data.generators import churn_network

    uni, ev = churn_network(n_initial_edges=60, n_events=900, seed=11)
    cut = 700
    with codec.using_codec("raw"):
        gm = GraphManager(uni, ev[:cut], L=64, k=2, cache_bytes=0)
    with codec.using_codec("v2"):
        gm.update(ev[cut:])                   # new leaves encode as v2
        tmax = int(ev.time[-1])
        for t in np.linspace(0, tmax, 12):
            st = gm.get_snapshot(int(t))
            tr = replay(uni, ev, int(t))
            assert np.array_equal(st.node_mask, tr.node_mask), t
            assert np.array_equal(st.edge_mask, tr.edge_mask), t
    gm.close()


# ---------------------------------------------------------------------------
# decoded-payload cache
# ---------------------------------------------------------------------------

def test_decode_cache_content_addressed():
    codec.set_decode_cache_bytes(1 << 20)
    try:
        a = {"x": np.arange(100, dtype=np.int64)}
        b = {"x": np.arange(1, 101, dtype=np.int64)}
        blob_a, blob_b = encode_blob(a), encode_blob(b)
        h0 = codec.decode_cache_stats["hits"]
        out1 = decode_blob(blob_a)
        out2 = decode_blob(bytes(blob_a))     # equal bytes, distinct object
        assert out2 is out1                   # served from the cache
        assert codec.decode_cache_stats["hits"] == h0 + 1
        # an overwrite (different bytes) can never alias the stale decode
        out3 = decode_blob(blob_b)
        assert np.array_equal(out3["x"], b["x"])
        # cached arrays are read-only: mutation fails loudly
        with pytest.raises(ValueError):
            out1["x"][0] = 99
    finally:
        codec.set_decode_cache_bytes(64 << 20)


def test_decode_cache_disabled():
    codec.set_decode_cache_bytes(0)
    try:
        blob = encode_blob({"x": np.arange(50, dtype=np.int32)})
        assert decode_blob(blob) is not decode_blob(blob)
    finally:
        codec.set_decode_cache_bytes(64 << 20)


# ---------------------------------------------------------------------------
# hypothesis properties (optional dep — the deterministic tests above pin
# the same edges where hypothesis is absent)
# ---------------------------------------------------------------------------

try:
    from hypothesis import given, settings, strategies as st
    _HAVE_HYPOTHESIS = True
except ImportError:          # pragma: no cover - environment dependent
    _HAVE_HYPOTHESIS = False

if _HAVE_HYPOTHESIS:
    @st.composite
    def _bundles(draw):
        n_arrays = draw(st.integers(1, 4))
        out = {}
        for i in range(n_arrays):
            dt = np.dtype(draw(st.sampled_from(ALL_DTYPES)))
            size = draw(st.integers(0, 200))
            if dt.kind == "f":
                vals = draw(st.lists(st.floats(allow_nan=False, width=32),
                                     min_size=size, max_size=size))
                a = np.asarray(vals, dt)
            elif dt.kind == "b":
                a = np.asarray(draw(st.lists(st.booleans(), min_size=size,
                                             max_size=size)), dt)
            else:
                info = np.iinfo(dt)
                vals = draw(st.lists(st.integers(int(info.min),
                                                 int(info.max)),
                                     min_size=size, max_size=size))
                a = np.asarray(vals, dt)
            if draw(st.booleans()):
                a = np.sort(a)
            out[f"a{i}"] = a
        return out

    @settings(deadline=None, max_examples=60)
    @given(_bundles(), st.sampled_from(["v2", "raw"]))
    def test_property_roundtrip(arrays, codec_name):
        _assert_roundtrip(arrays, codec_name)

    @settings(deadline=None, max_examples=60)
    @given(st.binary(max_size=200))
    def test_property_garbage_never_garbage_arrays(data):
        """Arbitrary bytes either decode (a structurally valid bundle) or
        raise CodecError — never a silent wrong result."""
        try:
            decode_blob(data)
        except CodecError:
            pass

    @settings(deadline=None, max_examples=40)
    @given(_bundles(), st.integers(0, 10**6))
    def test_property_corruption_detected(arrays, pos):
        blob = bytearray(encode_blob(arrays, codec="v2"))
        pos %= len(blob)
        if pos < 4:                  # clearing magic falls back to legacy
            return
        blob[pos] ^= 0x55
        try:
            decode_blob(bytes(blob))
        except CodecError:
            return
        # the checksum covers the body: a byte flip that still decodes must
        # have hit header metadata the decoder ignores (reserved/raw_size)
        assert pos in (6, 7) or 8 <= pos < 16, pos