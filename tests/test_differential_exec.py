"""Differential test harness for the three-backend retrieval stack.

Random event streams are executed through every backend and the results
must coincide *exactly* (masks) or within the solvers' convergence
tolerance (PageRank):

* **host**        — ``DeltaGraph.get_snapshots`` (HostExecutor over the
  plan IR, numpy states);
* **jax**         — ``execute_ir_jax`` (vmapped batched bitmap chains
  over the same IR);
* **incremental** — ``GraphManager.evolve`` (one planned retrieval +
  inter-snapshot event-slice advancement, ``core/temporal.py``), plus the
  batched device variant ``evolve_intervals_jax``;

all four against the brute-force ``replay`` oracle.

The seeded sweep below always runs (``N_EXAMPLES`` ≥ 200 examples, no
optional deps); when ``hypothesis`` is installed an additional
generative pass explores the same property with minimized
counterexamples.
"""
from __future__ import annotations

import os

import numpy as np
import pytest

from repro.core import GraphManager, replay
from repro.core.query import NO_ATTRS
from repro.data.generators import random_history
from repro.runtime.jax_exec import evolve_intervals_jax, execute_ir_jax

N_EXAMPLES = 200
CHUNK = 25          # seeds per parametrized case (progress + isolation)
ALGO_EVERY = 20     # PageRank/CC differential on every 20th example
                    # (fixpoint solvers jit-compile per universe shape —
                    # masks stay cheap, so they carry the 200-example sweep)

# REPRO_SHARDS=N (N > 1) re-runs the whole sweep with the history stored
# in N mod_hash partitions and a fifth backend — the sharded scatter/
# gather retriever — differenced against the same replay oracle.  CI's
# smoke job runs the suite once unsharded and once at --shards 4.
SHARDS = int(os.environ.get("REPRO_SHARDS", "1"))


def _case_times(rng, gm, ev) -> list[int]:
    """Query timepoints: random draws plus exact leaf boundaries (the
    historically risky off-by-one sites) plus the recent region."""
    tmax = int(ev.time[-1]) if len(ev) else 0
    times = [int(t) for t in rng.integers(-1, tmax + 2, 4)]
    lt = gm.dg.leaf_time
    if len(lt) > 1:
        li = int(rng.integers(1, len(lt)))
        times += [int(lt[li]), int(lt[li]) + 1]
    times.append(tmax)
    return sorted(dict.fromkeys(times))


def _build(seed: int) -> tuple:
    rng = np.random.default_rng(seed)
    n_events = int(rng.integers(40, 120))
    uni, ev = random_history(n_events, seed,
                             max_time_step=int(rng.integers(1, 3)))
    kw = {}
    if SHARDS > 1:
        kw = dict(num_partitions=SHARDS, partition_fn="mod_hash")
    gm = GraphManager(uni, ev, L=int(rng.choice([8, 16, 32])),
                      k=int(rng.choice([2, 3])), cache_bytes=0,
                      prefetch_workers=0, **kw)
    if SHARDS > 1:
        gm.enable_sharding(SHARDS)
    return rng, uni, ev, gm


def _check_masks(seed: int) -> None:
    rng, uni, ev, gm = _build(seed)
    times = _case_times(rng, gm, ev)

    host = gm.dg.get_snapshots(times, NO_ATTRS, pool=gm.pool)
    ir = gm.dg.plan_multipoint(times, NO_ATTRS, True)
    jx = execute_ir_jax(gm.dg, ir, pool=gm.pool)
    inc = gm.evolve(times, "masks")
    cut = max(1, len(times) // 2)
    dev = evolve_intervals_jax(gm.dg, [times[:cut], times[cut - 1:]],
                               pool=gm.pool)
    dev_flat = {t: m for d in dev for t, m in d.items()}
    shd = (gm.sharded.execute(gm.dg, ir, NO_ATTRS, pool=gm.pool)
           if gm.sharded is not None else None)

    for i, t in enumerate(times):
        truth = replay(uni, ev, t)
        backends = [
            ("host", (host[t].node_mask, host[t].edge_mask)),
            ("jax", jx[t]),
            ("incremental", inc.values[i]),
            ("jax-interval", dev_flat[t])]
        if shd is not None:
            backends.append(("sharded", (shd[t].node_mask,
                                         shd[t].edge_mask)))
        for name, (nm, em) in backends:
            assert np.array_equal(nm, truth.node_mask), (seed, t, name)
            assert np.array_equal(em, truth.edge_mask), (seed, t, name)
    gm.close()


def _check_algorithms(seed: int) -> None:
    """Incremental PageRank/CC vs per-snapshot recompute at the same
    convergence criterion: labels exactly equal, ranks within fp tol."""
    rng, uni, ev, gm = _build(seed)
    times = _case_times(rng, gm, ev)

    pr_inc = gm.evolve(times, "pagerank", tol=1e-9)
    pr_rec = gm.evolve(times, "pagerank", tol=1e-9, incremental=False)
    for t, a, b in zip(times, pr_inc.values, pr_rec.values):
        assert np.allclose(a, b, atol=1e-5), (seed, t, np.abs(a - b).max())

    cc_inc = gm.evolve(times, "components")
    cc_rec = gm.evolve(times, "components", incremental=False)
    for t, a, b in zip(times, cc_inc.values, cc_rec.values):
        assert np.array_equal(a, b), (seed, t)

    deg_inc = gm.evolve(times, "degree")
    deg_rec = gm.evolve(times, "degree", incremental=False)
    for t, a, b in zip(times, deg_inc.values, deg_rec.values):
        assert np.array_equal(a, b), (seed, t)
    gm.close()


@pytest.mark.parametrize("chunk", range(N_EXAMPLES // CHUNK))
def test_differential_masks_and_algorithms(chunk):
    for seed in range(chunk * CHUNK, (chunk + 1) * CHUNK):
        _check_masks(seed)
        if seed % ALGO_EVERY == 0:
            _check_algorithms(seed)


def test_differential_with_attrs():
    """Host backend with full attribute options stays the oracle the
    structure-only backends are differenced against."""
    for seed in (7, 77):
        rng, uni, ev, gm = _build(seed)
        from repro.core.query import parse_attr_options
        opts = parse_attr_options("+node:all+edge:all", uni)
        times = _case_times(rng, gm, ev)
        host = gm.dg.get_snapshots(times, opts, pool=gm.pool)
        inc = gm.evolve(times, "masks", attr_options=opts)
        for i, t in enumerate(times):
            truth = replay(uni, ev, t)
            assert truth.equal(host[t]), (seed, t)
            assert np.array_equal(inc.values[i][0], truth.node_mask)
            assert np.array_equal(inc.values[i][1], truth.edge_mask)
        gm.close()


# -- optional generative pass (hypothesis) ----------------------------------

try:
    from hypothesis import HealthCheck, given, settings
    from hypothesis import strategies as st
    _HAVE_HYPOTHESIS = True
except ImportError:  # pragma: no cover - exercised on minimal containers
    _HAVE_HYPOTHESIS = False


if _HAVE_HYPOTHESIS:
    @settings(max_examples=25, deadline=None,
              suppress_health_check=[HealthCheck.too_slow])
    @given(seed=st.integers(0, 10**6))
    def test_differential_hypothesis(seed):
        _check_masks(seed)

    @settings(max_examples=8, deadline=None,
              suppress_health_check=[HealthCheck.too_slow])
    @given(seed=st.integers(0, 10**6))
    def test_differential_hypothesis_algorithms(seed):
        _check_algorithms(seed)
