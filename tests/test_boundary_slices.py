"""Regression tests for interval slicing at exact leaf boundaries.

Audit outcome (this PR): every ``EventList.search_time`` call site —
``deltagraph._virtual_edges`` / ``_chain_edges`` (cost fractions),
``executor.ApplyRecent`` (recent slicing), ``events.replay`` — follows
one convention: a slice ``(lo, hi]`` selects rows with
``lo < time <= hi`` via ``side="right"`` searches, which *is* the
inclusive-upper/exclusive-lower bound planning assumes; the one
inclusive-*start* lookup (``get_interval``'s first covering leaf) was
expressed as ``_leaf_for_time(ts - 1)`` arithmetic and is now the
explicit ``side="left"`` search ``_first_leaf_covering``.  These tests
pin the exact-boundary behavior — duplicate timestamps straddling a
leaf cut are the canonical off-by-one trap — so a future regression to
mixed conventions fails loudly.
"""
import numpy as np
import pytest

from repro.core import GraphManager, replay
from repro.core.events import (EV_NEW_EDGE, EV_NEW_NODE, EV_TRANS_EDGE,
                               EV_TRANS_NODE)
from repro.core.query import NO_ATTRS, parse_attr_options
from repro.data.generators import random_history

# max_time_step=1 forces many duplicate timestamps, so leaf cuts land
# *inside* runs of equal times — the regression scenario
SEEDS = [0, 1, 2, 3, 4, 11, 23]


def _gm(seed, L=16):
    uni, ev = random_history(140, seed, max_time_step=1)
    return uni, ev, GraphManager(uni, ev, L=L, k=2, cache_bytes=0,
                                 prefetch_workers=0)


@pytest.mark.parametrize("seed", SEEDS)
def test_snapshots_at_exact_leaf_boundaries(seed):
    uni, ev, gm = _gm(seed)
    opts = parse_attr_options("+node:all+edge:all", uni)
    times = sorted({int(t) for lt in gm.dg.leaf_time
                    for t in (lt - 1, lt, lt + 1)})
    for t in times:
        truth = replay(uni, ev, t)
        got = gm.dg.get_snapshot(t, opts, pool=gm.pool)
        assert truth.equal(got), (seed, t)
    # multipoint plans chain partial-eventlist slices between exact
    # boundary times — same answer required
    multi = gm.dg.get_snapshots(times[:6], opts, pool=gm.pool)
    for t in times[:6]:
        assert replay(uni, ev, t).equal(multi[t]), (seed, t)
    gm.close()


@pytest.mark.parametrize("seed", SEEDS[:4])
def test_get_interval_inclusive_start_at_boundaries(seed):
    """[ts, te) interval semantics against a brute-force oracle, with both
    endpoints swept across exact leaf-boundary times."""
    uni, ev, gm = _gm(seed)
    lt = gm.dg.leaf_time
    pairs = [(lt[i], lt[j]) for i in range(len(lt))
             for j in range(i, len(lt))][:30]
    pairs += [(lt[i], lt[i] + 1) for i in range(len(lt))]
    for ts, te in pairs:
        res = gm.dg.get_interval(int(ts), int(te))
        m = (ev.time >= ts) & (ev.time < te)
        na = np.unique(ev.slot[m & (ev.etype == EV_NEW_NODE)]).astype(np.int32)
        ea = np.unique(ev.slot[m & (ev.etype == EV_NEW_EDGE)]).astype(np.int32)
        n_tr = int((m & np.isin(ev.etype,
                                (EV_TRANS_EDGE, EV_TRANS_NODE))).sum())
        assert np.array_equal(res["node_added"], na), (seed, ts, te)
        assert np.array_equal(res["edge_added"], ea), (seed, ts, te)
        assert res["transient_slot"].size == n_tr, (seed, ts, te)
    gm.close()


def test_first_leaf_covering_is_side_left():
    """The explicit side="left" lookup must agree with the legacy
    ``_leaf_for_time(ts - 1)`` arithmetic at every timestamp, and the
    returned eventlist must be the first that can hold rows >= ts."""
    uni, ev, gm = _gm(5)
    dg = gm.dg
    tmax = int(ev.time[-1])
    for ts in range(-2, tmax + 3):
        assert dg._first_leaf_covering(ts) == dg._leaf_for_time(ts - 1), ts
        li = dg._first_leaf_covering(ts)
        # no earlier eventlist may contain a row with time >= ts
        if li > 0:
            assert dg.leaf_time[li] < ts or li == len(dg.leaf_nids) - 1
    gm.close()


def test_recent_region_boundary_slices():
    """Timepoints at/around the last leaf boundary and inside the recent
    (unindexed) region, where slicing runs on the in-memory eventlist."""
    uni, ev, gm = _gm(9, L=48)  # 140 events, L=48 -> recent tail exists
    assert len(gm.dg.recent), "fixture must leave a recent tail"
    t_last = gm.dg.leaf_time[-1]
    tmax = int(ev.time[-1])
    times = sorted({t_last - 1, t_last, t_last + 1, tmax - 1, tmax, tmax + 1})
    opts = parse_attr_options("+node:all+edge:all", uni)
    for t in times:
        truth = replay(uni, ev, t)
        assert truth.equal(gm.dg.get_snapshot(t, opts, pool=gm.pool)), t
    multi = gm.dg.get_snapshots(times, NO_ATTRS, pool=gm.pool)
    for t in times:
        truth = replay(uni, ev, t)
        assert np.array_equal(multi[t].node_mask, truth.node_mask), t
        assert np.array_equal(multi[t].edge_mask, truth.edge_mask), t
    gm.close()


def test_evolve_slices_at_boundaries():
    """The temporal engine's (lo, hi] slices across leaf cuts reproduce
    the oracle at every boundary timepoint."""
    uni, ev, gm = _gm(13)
    times = sorted({int(t) for lt in gm.dg.leaf_time
                    for t in (lt, lt + 1)})
    res = gm.evolve(times, "masks")
    for t, (nm, em) in res:
        truth = replay(uni, ev, t)
        assert np.array_equal(nm, truth.node_mask), t
        assert np.array_equal(em, truth.edge_mask), t
    gm.close()
