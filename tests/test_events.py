"""Event model + oracle semantics (paper §3.1)."""
import numpy as np
import pytest

from repro.core.events import (EV_NEW_EDGE, EV_NEW_NODE, EventList,
                               GraphHistoryBuilder, MaterializedState,
                               apply_events, replay)


def build_tiny():
    b = GraphHistoryBuilder()
    b.add_node("a", 1, attrs={"w": 1.0})
    b.add_node("b", 2)
    b.add_edge("a", "b", 3, edge_id="e1")
    b.set_node_attr("a", "w", 2.0, 4)
    b.delete_edge("a", "b", 5)
    b.add_edge("a", "b", 6, edge_id="e2")
    b.transient_edge("a", "b", 7)
    return b.finalize()


def test_builder_and_replay():
    uni, ev = build_tiny()
    assert uni.num_nodes == 2
    assert uni.num_edges == 3  # e1, e2, transient
    s3 = replay(uni, ev, 3)
    assert s3.node_mask.sum() == 2 and s3.edge_mask.sum() == 1
    s5 = replay(uni, ev, 5)
    assert s5.edge_mask.sum() == 0  # deletion effective at its timestamp
    s6 = replay(uni, ev, 6)
    assert s6.edge_mask.sum() == 1
    assert s6.edge_mask[uni.edge_slot("e2")]
    s7 = replay(uni, ev, 7)
    assert s7.edge_mask.sum() == 1  # transient edges never in snapshots


def test_attr_old_values_recorded():
    uni, ev = build_tiny()
    s4 = replay(uni, ev, 4)
    col = uni.attr_col("node", "w")
    assert s4.node_attrs[uni.node_slot("a"), col] == 2.0
    s3 = replay(uni, ev, 3)
    assert s3.node_attrs[uni.node_slot("a"), col] == 1.0


def test_bidirectional_event_application():
    """G_{k-1} = G_k - E (paper §3.1)."""
    uni, ev = build_tiny()
    full = replay(uni, ev, 100)
    # walk back to t=3 by applying the tail backward
    hi = ev.search_time(3)
    back = apply_events(full, ev[hi:], forward=False)
    truth = replay(uni, ev, 3)
    assert np.array_equal(back.node_mask, truth.node_mask)
    assert np.array_equal(back.edge_mask, truth.edge_mask)


def test_ids_never_reused():
    uni, ev = build_tiny()
    assert uni.edge_slot("e1") != uni.edge_slot("e2")


def test_eventlist_concat_slice():
    uni, ev = build_tiny()
    parts = EventList.concat([ev[:3], ev[3:]])
    assert len(parts) == len(ev)
    assert np.array_equal(parts.time, ev.time)


def test_duplicate_node_add_raises():
    b = GraphHistoryBuilder()
    b.add_node("x", 1)
    with pytest.raises(ValueError):
        b.add_node("x", 2)
