"""Explicit lifecycle: create/close loops must not leak threads.

Every worker a GraphManager owns — the prefetch pool, shard workers, the
threaded ingest pipeline — and everything the query server stacks on top
(scheduler dispatcher + executor pool, per-session reader/writer
threads) must be joined by ``close()``, and ``close()`` must be
idempotent.  The load-bearing assertion is a *stable thread count*
across repeated create/use/close cycles.
"""
from __future__ import annotations

import threading
import time

import pytest

from repro.api.document import Q
from repro.core.ingest import IngestPipeline
from repro.core.manager import GraphManager
from repro.data.generators import churn_network


def _settled_thread_count(deadline_s: float = 5.0) -> int:
    """Thread count once it stops changing (daemon teardown can lag a
    beat behind ``join`` returning)."""
    last = threading.active_count()
    t0 = time.monotonic()
    while time.monotonic() - t0 < deadline_s:
        time.sleep(0.05)
        cur = threading.active_count()
        if cur == last:
            return cur
        last = cur
    return last


@pytest.fixture(scope="module")
def history():
    return churn_network(n_initial_edges=80, n_events=1200, seed=3)


def test_manager_create_close_loop_stable_threads(history):
    uni, ev = history
    base = _settled_thread_count()
    for i in range(3):
        gm = GraphManager(uni, ev, L=64, k=2, diff_fn="intersection")
        # exercise the lazy prefetch pool (batched retrieval spawns it)
        gm.get_snapshots([10, 40, 80, 120])
        gm.close()
        assert gm.closed
        gm.close()                      # idempotent
        assert _settled_thread_count() == base, f"leak on cycle {i}"


def test_manager_close_with_sharding_and_ingest(history):
    uni, ev = history
    base = _settled_thread_count()
    for i in range(2):
        gm = GraphManager(uni, ev[:800], L=48, k=2,
                          diff_fn="intersection", num_partitions=2,
                          partition_fn="mod_hash")
        gm.enable_sharding(2)
        gm._ingest = IngestPipeline(gm, group_events=64, threaded=True)
        gm._ingest.submit(ev[800:1000])
        gm._ingest.drain(timeout=30.0)
        gm.get_snapshots([10, 50, 90])
        gm.close()
        assert gm._ingest is None and gm.sharded is None
        assert gm.prefetcher is None
        assert _settled_thread_count() == base, f"leak on cycle {i}"


def test_manager_context_manager(history):
    uni, ev = history
    with GraphManager(uni, ev, L=64, k=2) as gm:
        st = gm.get_snapshot(100)
        assert st.node_mask.any()
    assert gm.closed


def test_queries_after_close_degrade_gracefully(history):
    """Post-close retrieval must not respawn worker threads."""
    uni, ev = history
    gm = GraphManager(uni, ev, L=64, k=2)
    gm.get_snapshots([10, 40])
    gm.close()
    base = _settled_thread_count()
    st = gm.get_snapshots([10, 40, 80])
    assert len(st) == 3
    assert _settled_thread_count() == base
    gm.close()


def test_server_create_close_loop_stable_threads(history):
    import json
    import socket

    from repro.launch.server import QueryServer

    uni, ev = history
    gm = GraphManager(uni, ev, L=64, k=2)
    base = _settled_thread_count()
    for i in range(3):
        srv = QueryServer(gm, window_ms=1.0, workers=2).start()
        with socket.create_connection((srv.host, srv.port)) as s:
            f = s.makefile("rw", encoding="utf-8", newline="\n")
            f.write(Q.at(50).build().to_json() + "\n")
            f.flush()
            env = json.loads(f.readline())
            assert env["ok"]
        srv.close()
        srv.close()                     # idempotent
        assert _settled_thread_count() == base, f"leak on cycle {i}"
    gm.close()
