"""QueryService: differential suite (every legacy GraphManager entry point
vs its GraphQuery equivalent, bit-identical on the churn fixture, both
checked against the replay oracle), co-batched plan merging, stats
envelopes, and the serve.py wire loop."""
import json

import numpy as np
import pytest

from repro.api import GraphQuery, Q
from repro.core import GraphManager, TimeExpression, replay
from repro.core.query import parse_attr_options

from conftest import assert_state_equal


@pytest.fixture(scope="module")
def gm(churn):
    uni, ev = churn
    g = GraphManager(uni, ev, L=100, k=2, diff_fn="balanced")
    yield g
    g.close()


def _times(ev, *idx):
    return [int(ev.time[i]) for i in idx]


# ---------------------------------------------------------------------------
# differential: legacy entry point == GraphQuery equivalent == oracle
# ---------------------------------------------------------------------------


def test_snapshot_differential(gm, churn):
    uni, ev = churn
    for t in _times(ev, 150, 700, 1150):
        legacy = gm.get_snapshot(t, "+node:all+edge:all")
        doc = Q.at(t).attrs("+node:all+edge:all").build()
        via_doc = gm.query.run(doc).value
        oracle = replay(uni, ev, t)
        assert_state_equal(via_doc, legacy, msg=f"t={t}")
        assert legacy.equal(via_doc)
        assert_state_equal(via_doc, oracle, msg=f"t={t} vs oracle")
        assert oracle.equal(via_doc)


def test_multipoint_differential(gm, churn):
    uni, ev = churn
    ts = _times(ev, 100, 400, 800, 1100)
    legacy = gm.get_snapshots(ts, "+node:all")
    res = gm.query.run(Q.at(ts).attrs("+node:all").build())
    assert sorted(res.value) == sorted(legacy)
    for t in ts:
        assert legacy[t].equal(res.value[t])
        assert_state_equal(res.value[t], replay(uni, ev, t))
    assert res.stats["targets"] == len(ts)


def test_expr_differential(gm, churn):
    uni, ev = churn
    t1, t2 = _times(ev, 300, 1000)
    tex = TimeExpression.parse("t0 & ~t1", [t1, t2])
    legacy = gm.get_hist_graph_expr(tex, "+node:all")
    res = gm.query.run(Q.expr("t0 & ~t1", [t1, t2]).attrs("+node:all")
                       .build())
    tr1, tr2 = replay(uni, ev, t1), replay(uni, ev, t2)
    assert np.array_equal(legacy.node_mask, res.value.node_mask)
    assert np.array_equal(legacy.edge_mask, res.value.edge_mask)
    assert np.array_equal(res.value.edge_mask,
                          tr1.edge_mask & ~tr2.edge_mask)
    # HistGraph escape hatch reproduces the document's state bit-for-bit
    assert legacy.to_state().equal(res.value)
    legacy.close()


def test_interval_differential(gm, churn):
    uni, ev = churn
    ts, te = _times(ev, 200, 900)
    legacy = gm.get_hist_graph_interval(ts, te)
    via_doc = gm.query.run(Q.between(ts, te).build()).value
    for k in legacy:
        assert np.array_equal(legacy[k], via_doc[k]), k


def test_evolve_differential(gm, churn):
    uni, ev = churn
    ts = sorted(_times(ev, 500, 600, 700, 800))
    legacy = gm.evolve(ts, "degree")
    res = gm.query.run(Q.evolve(ts, "degree").build())
    assert legacy.times == res.value.times
    for a, b in zip(legacy.values, res.value.values):
        assert np.array_equal(a, b)
    # masks agree with the oracle at every point
    masks = gm.query.run(Q.evolve(ts, "masks").build()).value
    for t, (nm, em) in masks:
        truth = replay(uni, ev, t)
        assert np.array_equal(nm[: truth.node_mask.size], truth.node_mask)
        assert np.array_equal(em[: truth.edge_mask.size], truth.edge_mask)


def test_hist_graphs_use_current_threaded(gm, churn):
    uni, ev = churn
    ts = _times(ev, 350, 1050)
    hs = gm.get_hist_graphs(ts, use_current=False)
    hs2 = gm.get_hist_graphs(ts)   # default still routes through current
    for h, h2, t in zip(hs, hs2, ts):
        truth = replay(uni, ev, t)
        assert np.array_equal(h.node_mask, truth.node_mask)
        assert np.array_equal(h.edge_mask, h2.edge_mask)
        h.close()
        h2.close()


# ---------------------------------------------------------------------------
# batching, stats, errors
# ---------------------------------------------------------------------------


def test_run_batch_merges_point_documents(churn):
    uni, ev = churn
    with GraphManager(uni, ev, L=100, k=2, cache_bytes=0) as g:
        ts = _times(ev, 100, 500, 900)
        docs = [Q.at(ts[0]).build(), Q.at(ts[1:]).build(),
                Q.expr("t0 | t1", ts[:2]).build(),
                Q.between(ts[0], ts[1]).build()]
        results = g.query.run_batch(docs)
        assert [r.kind for r in results] == ["snapshot", "multipoint",
                                             "expr", "interval"]
        assert all(r.ok for r in results)
        # the three point documents shared ONE merged Steiner plan
        assert results[0].stats["merged_docs"] == 3
        assert results[0].stats["targets"] == 3        # distinct times
        assert results[0].stats["plan_cost"] == \
            results[1].stats["plan_cost"]
        assert "merged_docs" not in results[3].stats
        for t, st in results[1].value.items():
            assert_state_equal(st, replay(uni, ev, t), check_attrs=False)


def test_run_batch_error_isolation(gm, churn):
    uni, ev = churn
    t = int(ev.time[400])
    docs = [Q.at(t).build(),
            GraphQuery(kind="snapshot", t=t, attrs="+node:missing"),
            GraphQuery(kind="expr", expr="t0 &", times=(t,))]
    results = gm.query.run_batch(docs, on_error="envelope")
    assert results[0].ok
    assert not results[1].ok
    assert results[1].error.code == "unknown-attribute"
    assert not results[2].ok
    assert results[2].error.code == "time-expression"
    from repro.api import TimeExpressionError
    with pytest.raises(TimeExpressionError):
        gm.query.run_batch([docs[2]])       # on_error="raise" default


def test_stats_envelope_fields(churn):
    uni, ev = churn
    with GraphManager(uni, ev, L=100, k=2) as g:
        t = int(ev.time[800])
        r1 = g.query.run(Q.at(t).build())
        assert r1.stats["kv_gets"] > 0 and r1.stats["kv_bytes"] > 0
        assert r1.stats["plan_cost"] > 0 and r1.stats["wall_s"] > 0
        assert r1.stats["cache_hits"] == 0
        r2 = g.query.run(Q.at(t).build())            # exact-repeat hit
        assert r2.stats["cache_hits"] == 1 and r2.stats["kv_gets"] == 0
        assert r2.value.equal(r1.value)
        r3 = g.query.run(Q.at(t).fresh().build())    # consistency hint
        assert r3.stats["cache_hits"] == 0 and r3.stats["kv_gets"] > 0
        assert r3.value.equal(r1.value)


def test_envelope_json_shape(gm, churn):
    uni, ev = churn
    t = int(ev.time[300])
    env = json.loads(gm.query.run(Q.at(t).build()).to_json())
    assert env["ok"] and env["v"] == 1 and env["kind"] == "snapshot"
    truth = replay(uni, ev, t)
    assert env["result"]["nodes"] == int(truth.node_mask.sum())
    assert env["result"]["edges"] == int(truth.edge_mask.sum())
    assert set(env["stats"]) >= {"wall_s", "kv_gets", "kv_bytes",
                                 "plan_cost", "cache_hits"}
    # full reply carries the live slot lists
    full = json.loads(gm.query.run(Q.at(t).full().build()).to_json())
    assert full["result"]["node_slots"] == \
        np.nonzero(truth.node_mask)[0].tolist()
    # deterministic: same document, same payload CRCs
    env2 = json.loads(gm.query.run(Q.at(t).build()).to_json())
    assert env2["result"] == env["result"]


# ---------------------------------------------------------------------------
# the serve.py wire loop
# ---------------------------------------------------------------------------


def test_wire_loop_in_process(churn):
    from repro.launch.serve import run_query_documents
    uni, ev = churn
    with GraphManager(uni, ev, L=100, k=2) as g:
        t1, t2 = int(ev.time[200]), int(ev.time[1000])
        lines = [
            json.dumps({"kind": "multipoint", "times": [t1, t2]}),
            "",                                        # blank lines skipped
            json.dumps({"kind": "snapshot", "t": t1}),
            "this is not json",
            json.dumps({"kind": "evolve", "times": [t1, t1 + 50],
                        "op": "density"}),
            json.dumps({"kind": "snapshot"}),          # invalid document
        ]
        envs = [json.loads(s) for s in run_query_documents(g, lines,
                                                           batch=3)]
    assert [e["ok"] for e in envs] == [True, True, False, True, False]
    assert envs[0]["kind"] == "multipoint"
    assert {p["t"] for p in envs[0]["result"]["points"]} == {t1, t2}
    truth = replay(uni, ev, t1)
    assert envs[1]["result"]["nodes"] == int(truth.node_mask.sum())
    assert envs[2]["error"]["kind"] == "document"
    assert envs[3]["result"]["values"][0]["nodes"] == \
        int(truth.node_mask.sum())
    assert envs[4]["error"]["kind"] == "document"
    assert envs[4]["error"]["position"] == "t"


@pytest.mark.slow
def test_wire_loop_subprocess():
    """The acceptance-criterion invocation: echo a document into
    ``python -m repro.launch.serve --mode query`` and get a valid JSON
    envelope with execution stats back."""
    import os
    import subprocess
    import sys

    root = os.path.join(os.path.dirname(__file__), "..")
    doc = '{"kind": "multipoint", "times": [50, 150], "attrs": ""}\n'
    proc = subprocess.run(
        [sys.executable, "-m", "repro.launch.serve", "--mode", "query",
         "--events", "1500"],
        input=doc, capture_output=True, text=True, timeout=300,
        env={**os.environ, "PYTHONPATH": "src"}, cwd=root)
    assert proc.returncode == 0, proc.stderr
    env = json.loads(proc.stdout.strip())
    assert env["ok"] and env["kind"] == "multipoint"
    assert len(env["result"]["points"]) == 2
    assert env["stats"]["kv_gets"] > 0
    assert "served 1 documents (1 ok)" in proc.stderr


def test_poisoned_document_fails_alone_in_merged_group(churn, monkeypatch):
    """A merged group containing a document that fails *after* the shared
    retrieval (at finish) returns an error envelope for that document
    only — ordering pinned to input order, the survivors keep the shared
    plan's stats with per-document attribution."""
    import repro.api.compiler as compiler_mod

    uni, ev = churn
    with GraphManager(uni, ev, L=100, k=2, cache_bytes=0) as g:
        ts = _times(ev, 100, 500, 900)
        docs = [Q.at(ts[0]).build(),
                Q.expr("t0 | t1", ts[:2]).build(),   # poisoned below
                Q.at(ts[1:]).build()]

        def boom(tex, states, *a, **k):
            raise RuntimeError("poisoned finish")

        monkeypatch.setattr(compiler_mod, "expr_state", boom)
        results = g.query.run_batch(docs, on_error="envelope")

        # response ordering pinned to input order
        assert [r.kind for r in results] == ["snapshot", "expr",
                                             "multipoint"]
        assert [r.ok for r in results] == [True, False, True]
        assert results[1].error.code == "execution"
        assert "poisoned finish" in str(results[1].error)
        # the survivors shared one merged plan with the poisoned doc...
        assert results[0].stats["merged_docs"] == 3
        assert results[2].stats["merged_docs"] == 3
        assert results[0].stats["targets"] == 3       # distinct times
        # ...with per-document stats attribution on top
        assert results[0].stats["doc_targets"] == 1
        assert results[2].stats["doc_targets"] == 2
        # and the survivors' payloads are still oracle-exact
        assert_state_equal(results[0].value, replay(uni, ev, ts[0]),
                           check_attrs=False)
        for t, st in results[2].value.items():
            assert_state_equal(st, replay(uni, ev, t), check_attrs=False)

        # on_error="raise" propagates the same failure
        monkeypatch.setattr(compiler_mod, "expr_state", boom)
        with pytest.raises(Exception, match="poisoned finish"):
            g.query.run_batch(docs, on_error="raise")
