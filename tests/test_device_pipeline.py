"""Double-buffered device pipeline + fused retrieval + snapshot loader.

Covers the streaming staging layer (`runtime/staging.py`), the chunked
`execute_ir_jax` / `evolve_intervals_jax` paths (must stay bit-identical
to the monolithic call — chunked chain application is a left fold), the
fused singlepoint analytics entry, and `SnapshotBatchLoader`.
"""
import numpy as np
import pytest

import jax.numpy as jnp

from repro.core import GraphManager, SnapshotBatchLoader, replay
from repro.core.query import NO_ATTRS
from repro.data.generators import random_history
from repro.runtime.jax_exec import (evolve_intervals_jax,
                                    execute_ir_jax,
                                    execute_singlepoint_fused)
from repro.runtime.staging import DeviceStager, stream_chunk_k


# ---------------------------------------------------------------------------
# DeviceStager
# ---------------------------------------------------------------------------


def test_stager_overlap_order():
    """With depth=2, chunk i+1 is built and put before chunk i's apply is
    issued — the double-buffering contract, visible in the event log."""
    st = DeviceStager(depth=2, put_fn=lambda x: x)
    chunks = [(np.full(4, i),) for i in range(5)]
    seen = []

    def apply(carry, dev):
        seen.append(int(dev[0][0]))
        return carry + dev[0].sum()

    out = st.stream(5, lambda i: chunks[i], apply, 0)
    assert out == sum(np.full(4, i).sum() for i in range(5))
    assert seen == [0, 1, 2, 3, 4]
    puts = [i for kind, i in st.events if kind == "put"]
    applies = [i for kind, i in st.events if kind == "apply"]
    assert puts == [0, 1, 2, 3, 4] and applies == [0, 1, 2, 3, 4]
    # put(1) precedes apply(0): one chunk always staged ahead
    assert st.events.index(("put", 1)) < st.events.index(("apply", 0))
    assert st.events.index(("put", 2)) < st.events.index(("apply", 1))


def test_stager_depth_bound():
    """Never more than `depth` puts ahead of the apply cursor — resident
    staging memory is bounded."""
    st = DeviceStager(depth=3, put_fn=lambda x: x)
    ahead = []

    def apply(carry, dev):
        puts = sum(1 for k, _ in st.events if k == "put")
        applies = sum(1 for k, _ in st.events if k == "apply")
        ahead.append(puts - applies)
        return carry

    st.stream(8, lambda i: (np.zeros(1),), apply, None)
    assert max(ahead) <= 3


def test_stager_empty_and_validation():
    st = DeviceStager(put_fn=lambda x: x)
    assert st.stream(0, lambda i: (), lambda c, d: c, "carry") == "carry"
    with pytest.raises(ValueError):
        DeviceStager(depth=0)


def test_stager_with_prefetcher_builds_on_worker():
    from repro.runtime.executor import Prefetcher
    from repro.storage.kv import MemKV
    import threading
    pf = Prefetcher(MemKV(), workers=2)
    main = threading.get_ident()
    build_threads = []

    def build(i):
        build_threads.append(threading.get_ident())
        return (np.full(2, i),)

    st = DeviceStager(depth=2, put_fn=lambda x: x, prefetcher=pf)
    out = st.stream(4, build, lambda c, d: c + int(d[0][0]), 0)
    assert out == 0 + 1 + 2 + 3
    assert all(t != main for t in build_threads)   # built off-thread
    pf.close(wait=True)


def test_stream_chunk_env(monkeypatch):
    monkeypatch.setenv("REPRO_STREAM_CHUNK", "3")
    assert stream_chunk_k() == 3
    monkeypatch.setenv("REPRO_STREAM_CHUNK", "junk")
    assert stream_chunk_k() == 8
    monkeypatch.delenv("REPRO_STREAM_CHUNK")
    assert stream_chunk_k() == 8


# ---------------------------------------------------------------------------
# streamed execution == monolithic execution (bit-identical)
# ---------------------------------------------------------------------------


def _build(seed=3, n_events=150):
    uni, ev = random_history(n_events, seed, max_time_step=2)
    gm = GraphManager(uni, ev, L=8, k=2, cache_bytes=0, prefetch_workers=0)
    return uni, ev, gm


def test_streamed_ir_bit_identical(monkeypatch):
    uni, ev, gm = _build()
    tmax = int(ev.time[-1])
    times = sorted({0, tmax // 3, tmax // 2, tmax})
    ir = gm.dg.plan_multipoint(times, NO_ATTRS, True)
    mono = execute_ir_jax(gm.dg, ir, pool=gm.pool)
    monkeypatch.setenv("REPRO_STREAM_CHUNK", "1")   # force max chunking
    stager = DeviceStager()
    streamed = execute_ir_jax(gm.dg, ir, pool=gm.pool, stager=stager)
    assert any(k == "apply" for k, _ in stager.events)  # streaming engaged
    for t in times:
        assert np.array_equal(mono[t][0], streamed[t][0]), t
        assert np.array_equal(mono[t][1], streamed[t][1]), t
        truth = replay(uni, ev, t)
        assert np.array_equal(streamed[t][0], truth.node_mask), t
        assert np.array_equal(streamed[t][1], truth.edge_mask), t
    gm.close()


def test_streamed_evolve_bit_identical(monkeypatch):
    uni, ev, gm = _build(seed=4)
    tmax = int(ev.time[-1])
    iv = list(range(0, tmax + 1, max(1, tmax // 9)))
    mono = evolve_intervals_jax(gm.dg, [iv], pool=gm.pool)
    monkeypatch.setenv("REPRO_STREAM_CHUNK", "2")
    stager = DeviceStager()
    streamed = evolve_intervals_jax(gm.dg, [iv], pool=gm.pool,
                                    stager=stager)
    for t in iv:
        assert np.array_equal(mono[0][t][0], streamed[0][t][0]), t
        assert np.array_equal(mono[0][t][1], streamed[0][t][1]), t
        truth = replay(uni, ev, t)
        assert np.array_equal(streamed[0][t][0], truth.node_mask), t
    gm.close()


# ---------------------------------------------------------------------------
# fused singlepoint retrieval + analytics
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("seed", [0, 7])
def test_fused_singlepoint_vs_replay(seed):
    uni, ev, gm = _build(seed=seed, n_events=100)
    rng = np.random.default_rng(seed)
    tmax = int(ev.time[-1])
    for t in (0, tmax // 2, tmax):
        w = rng.random(uni.num_nodes, dtype=np.float32)
        nm, em, an = execute_singlepoint_fused(gm.dg, t, node_weights=w,
                                               pool=gm.pool)
        truth = replay(uni, ev, t)
        assert np.array_equal(nm, truth.node_mask), t
        assert np.array_equal(em, truth.edge_mask), t
        assert an.num_nodes() == int(truth.node_mask.sum())
        assert an.num_edges() == int(truth.edge_mask.sum())
        # weighted push mass == Σ weights over live nodes, exactly (the
        # per-word partials fix the reduction grouping)
        ref = np.zeros(uni.num_nodes, np.float32)
        ref[truth.node_mask] = w[truth.node_mask]
        assert np.float32(an.node.weighted_total()) == np.float32(
            ref.reshape(-1, 1).sum(dtype=np.float32)) or np.isclose(
            an.node.weighted_total(), ref.sum(dtype=np.float32), rtol=1e-6)
        # degrees from the fused live feed == host scatter
        deg = an.degrees()
        rd = np.zeros(uni.num_nodes, np.float32)
        E = uni.num_edges
        for e in np.nonzero(truth.edge_mask)[0]:
            rd[uni.edge_src[e]] += 1
            rd[uni.edge_dst[e]] += 1
        assert np.array_equal(deg, rd), t
    gm.close()


# ---------------------------------------------------------------------------
# SnapshotBatchLoader
# ---------------------------------------------------------------------------


def test_snapshot_batch_loader_oracle():
    uni, ev, gm = _build(seed=11, n_events=200)
    tmax = int(ev.time[-1])
    times = list(range(0, tmax, max(1, tmax // 10)))
    loader = SnapshotBatchLoader(gm, times, batch_size=3, label_horizon=4,
                                 d_in=8)
    N, E = uni.num_nodes, uni.num_edges
    n_batches = 0
    for batch in loader:
        T = len(batch["times"])
        assert batch["x"].shape == (T, N, 8)
        assert batch["edge_index"].shape == (2, 2 * E)
        assert batch["edge_mask"].shape == (T, 2 * E)
        assert batch["labels"].shape == (T, N)
        for j, t in enumerate(batch["times"]):
            truth = replay(uni, ev, t)
            assert np.array_equal(
                np.asarray(batch["label_mask"][j]) > 0, truth.node_mask)
            rd = np.zeros(N, np.float32)
            eid = np.nonzero(truth.edge_mask)[0]
            np.add.at(rd, uni.edge_src[eid], 1)
            np.add.at(rd, uni.edge_dst[eid], 1)
            assert np.array_equal(np.asarray(batch["x"][j, :, -1]), rd)
            assert int(batch["num_edges"][j]) == int(truth.edge_mask.sum())
            fut = replay(uni, ev, t + 4)
            fd = np.zeros(N, np.float32)
            eid2 = np.nonzero(fut.edge_mask)[0]
            np.add.at(fd, uni.edge_src[eid2], 1)
            np.add.at(fd, uni.edge_dst[eid2], 1)
            assert np.array_equal(np.asarray(batch["labels"][j]),
                                  (fd > rd).astype(np.int32))
        n_batches += 1
    assert n_batches == len(loader) == len(times) // 3
    gm.close()


def test_snapshot_batch_loader_no_horizon():
    uni, ev, gm = _build(seed=12, n_events=80)
    tmax = int(ev.time[-1])
    loader = SnapshotBatchLoader(gm, [0, tmax // 2, tmax], batch_size=3)
    (batch,) = list(loader)
    assert "labels" not in batch
    assert batch["x"].shape[0] == 3
    with pytest.raises(ValueError):
        SnapshotBatchLoader(gm, [0], batch_size=0)
    gm.close()


# ---------------------------------------------------------------------------
# prefetcher worker plumbing
# ---------------------------------------------------------------------------


def test_prefetcher_submit_fn_and_decode_nice():
    from repro.runtime.executor import Prefetcher
    from repro.storage import codec
    from repro.storage.kv import MemKV
    pf = Prefetcher(MemKV(), workers=1)
    assert pf.submit_fn(lambda a, b: a + b, 2, 3).result() == 5

    # the worker installs a decode-nice hook; verify the hook fires in
    # _decode_v2 by installing a counting hook on this thread
    calls = []
    codec.set_decode_nice(lambda: calls.append(1))
    try:
        blob = codec.encode_blob(
            {"a": np.arange(5), "b": np.ones(3, np.float32)}, codec="v2")
        codec.set_decode_cache_bytes(0)     # bypass the decode cache
        out = codec.decode_blob(blob)
        assert np.array_equal(out["a"], np.arange(5))
        assert len(calls) == 2              # once per array
    finally:
        codec.set_decode_nice(None)
        codec.set_decode_cache_bytes(64 * 2 ** 20)
    pf.close(wait=True)
