"""RPC transport layer: framing, deadlines, connection pooling, and the
typed error taxonomy the fault layer keys on.

The load-bearing properties:

* frames round-trip bit-exactly, including the ``None``-blob sentinel
  (a *missing* KV value is distinct from an empty one);
* every failure is classified — :class:`RpcConnectionError` /
  :class:`RpcTimeout` are retryable (another attempt can win),
  :class:`RpcProtocolError` is fatal (a codec bug re-fails), and
  :class:`RemoteCallError` inherits the server's classification of the
  handler exception;
* a remote handler failure carries the *server-side* traceback through
  the boundary, so ``str(e)`` shows where the worker actually failed.
"""
from __future__ import annotations

import socket
import struct
import threading
import time

import pytest

from repro.runtime.fault import default_retryable, retry
from repro.runtime.rpc import (KIND_ERROR, KIND_REQUEST, KIND_RESPONSE,
                               RemoteCallError, RpcClient,
                               RpcConnectionError, RpcProtocolError,
                               RpcServer, RpcTimeout, pack_frame, read_frame)


def _loop_pair():
    """A connected (client, server) socket pair over loopback."""
    lst = socket.socket()
    lst.bind(("127.0.0.1", 0))
    lst.listen(1)
    c = socket.create_connection(lst.getsockname())
    s, _ = lst.accept()
    lst.close()
    return c, s


# ---------------------------------------------------------------------------
# framing
# ---------------------------------------------------------------------------

def test_frame_roundtrip_with_none_sentinel():
    blobs = [b"hello", None, b"", b"\x00" * 257, None]
    frame = pack_frame(KIND_RESPONSE, 42,
                       {"r": {"n": 3}, "extra": "é"}, blobs)
    c, s = _loop_pair()
    try:
        c.sendall(frame)
        kind, rid, header, out = read_frame(s)
        assert kind == KIND_RESPONSE
        assert rid == 42
        assert header == {"r": {"n": 3}, "extra": "é"}
        assert out == blobs               # None != b"" — holes survive
        assert out[2] == b"" and out[1] is None
    finally:
        c.close()
        s.close()


@pytest.mark.parametrize("raw", [
    struct.pack("<I", 5),                              # length < minimum
    struct.pack("<I", (1 << 30) + 1),                  # length > MAX_FRAME
    struct.pack("<I", 13) + struct.pack("<BQI", 7, 1, 0),   # bad kind
    struct.pack("<I", 13) + struct.pack("<BQI", 0, 1, 999),  # header overrun
])
def test_corrupt_frames_are_protocol_errors(raw):
    c, s = _loop_pair()
    try:
        c.sendall(raw)
        with pytest.raises(RpcProtocolError) as ei:
            read_frame(s)
        assert ei.value.retryable is False
    finally:
        c.close()
        s.close()


def test_midframe_eof_is_retryable_connection_error():
    c, s = _loop_pair()
    try:
        c.sendall(struct.pack("<I", 100) + b"partial")
        c.close()
        with pytest.raises(RpcConnectionError) as ei:
            read_frame(s)
        assert ei.value.retryable is True
    finally:
        s.close()


# ---------------------------------------------------------------------------
# client <-> server calls
# ---------------------------------------------------------------------------

def _echo_handlers():
    def h_echo(args, blobs):
        return {"args": args, "n": len(blobs)}, list(blobs)

    def h_boom_io(args, blobs):
        raise IOError("disk hiccup")

    def h_boom_val(args, blobs):
        raise ValueError("bad argument shape")

    def h_slow(args, blobs):
        time.sleep(float(args.get("s", 1.0)))
        return "late"

    return {"echo": h_echo, "boom_io": h_boom_io,
            "boom_val": h_boom_val, "slow": h_slow}


def test_call_roundtrip_and_pool_reuse():
    with RpcServer(_echo_handlers()) as srv:
        cli = RpcClient(srv.host, srv.port)
        try:
            for i in range(5):
                res, blobs = cli.call("echo", {"i": i},
                                      blobs=[b"x" * i, None])
                assert res == {"args": {"i": i}, "n": 2}
                assert blobs == [b"x" * i, None]
            # sequential calls reuse one pooled connection
            assert cli.dials == 1
            assert cli.calls == 5
            assert srv.requests == 5 and srv.errors == 0
        finally:
            cli.close()


def test_concurrent_calls_use_distinct_connections():
    with RpcServer(_echo_handlers()) as srv:
        cli = RpcClient(srv.host, srv.port, pool_size=8)
        out: dict[int, dict] = {}

        def one(i: int) -> None:
            res, _ = cli.call("slow" if i % 3 == 0 else "echo",
                              {"i": i, "s": 0.05})
            out[i] = res

        try:
            ts = [threading.Thread(target=one, args=(i,)) for i in range(9)]
            for t in ts:
                t.start()
            for t in ts:
                t.join()
            assert len(out) == 9
            for i in range(9):
                if i % 3 == 0:
                    assert out[i] == "late"
                else:
                    assert out[i]["args"]["i"] == i
            assert cli.dials >= 2          # concurrency forced extra dials
        finally:
            cli.close()


def test_remote_error_carries_traceback_and_classification():
    with RpcServer(_echo_handlers()) as srv:
        cli = RpcClient(srv.host, srv.port)
        try:
            with pytest.raises(RemoteCallError) as ei:
                cli.call("boom_val", {})
            e = ei.value
            assert e.retryable is False            # ValueError: fatal
            assert e.remote_type == "ValueError"
            assert "bad argument shape" in str(e)
            assert "--- remote traceback ---" in str(e)
            assert "h_boom_val" in e.remote_traceback   # server-side frame

            with pytest.raises(RemoteCallError) as ei:
                cli.call("boom_io", {})
            assert ei.value.retryable is True      # IOError: transient
            assert default_retryable(ei.value)

            with pytest.raises(RemoteCallError) as ei:
                cli.call("no_such_method", {})
            assert ei.value.retryable is False
            assert ei.value.remote_type == "KeyError"
        finally:
            cli.close()


def test_remote_traceback_survives_fault_retry():
    """fault.retry re-raises the exception *object*, so the remote frames
    ride along in the message after retries are exhausted."""
    with RpcServer(_echo_handlers()) as srv:
        cli = RpcClient(srv.host, srv.port)
        try:
            with pytest.raises(RemoteCallError) as ei:
                retry(lambda: cli.call("boom_io", {}), attempts=2,
                      base_delay=0.001, retryable=default_retryable)
            assert "h_boom_io" in str(ei.value)
            assert srv.errors >= 2                 # it really retried
        finally:
            cli.close()


def test_deadline_timeout_is_retryable():
    with RpcServer(_echo_handlers()) as srv:
        cli = RpcClient(srv.host, srv.port)
        try:
            t0 = time.monotonic()
            with pytest.raises(RpcTimeout) as ei:
                cli.call("slow", {"s": 5.0}, deadline_s=0.2)
            assert time.monotonic() - t0 < 2.0     # deadline, not handler
            assert ei.value.retryable is True
            assert default_retryable(ei.value)
            # the poisoned socket was discarded: the next call re-dials
            # a clean connection and succeeds
            res, _ = cli.call("echo", {"ok": 1})
            assert res["args"] == {"ok": 1}
            assert cli.dials >= 2
        finally:
            cli.close()


def test_dial_failure_is_retryable_connection_error():
    # grab a port nothing listens on
    probe = socket.socket()
    probe.bind(("127.0.0.1", 0))
    port = probe.getsockname()[1]
    probe.close()
    cli = RpcClient("127.0.0.1", port, connect_timeout=0.5)
    try:
        with pytest.raises(RpcConnectionError) as ei:
            cli.call("echo", {})
        assert ei.value.retryable is True
    finally:
        cli.close()


def test_response_id_mismatch_is_fatal():
    lst = socket.socket()
    lst.bind(("127.0.0.1", 0))
    lst.listen(1)

    def bad_server():
        conn, _ = lst.accept()
        read_frame(conn)
        conn.sendall(pack_frame(KIND_RESPONSE, 999_999, {"r": "wrong"}))
        conn.close()

    t = threading.Thread(target=bad_server, daemon=True)
    t.start()
    cli = RpcClient(*lst.getsockname())
    try:
        with pytest.raises(RpcProtocolError) as ei:
            cli.call("echo", {})
        assert ei.value.retryable is False
        # the desynchronized socket was closed, not pooled: a stray frame
        # must never be handed to whichever call borrows the socket next
        assert cli._idle == []
    finally:
        cli.close()
        lst.close()
        t.join(timeout=2.0)


def test_server_close_is_idempotent_and_frees_port():
    srv = RpcServer(_echo_handlers()).start()
    port = srv.port
    srv.close()
    srv.close()
    # port is reusable immediately (SO_REUSEADDR + real close)
    s = socket.socket()
    s.setsockopt(socket.SOL_SOCKET, socket.SO_REUSEADDR, 1)
    s.bind(("127.0.0.1", port))
    s.close()


def test_request_kind_constant_sanity():
    # the wire protocol is frozen: these values are part of the format
    assert (KIND_REQUEST, KIND_RESPONSE, KIND_ERROR) == (0, 1, 2)
