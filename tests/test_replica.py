"""Replicated shard processes: placement, distinct-replica hedging, the
process transport's bit-identity, chaos (SIGKILL) failover, and
epoch-driven cache invalidation.

The replication contract under test (ISSUE: replicated shard processes):

* rendezvous ranking gives every partition ``R`` candidate servers with
  rank 0 identical to the legacy ``elastic_replan`` primary, and removing
  a server moves exactly its partitions (minimal reassignment);
* a hedged or requeued attempt routes to a candidate **distinct from the
  servers already tried** whenever one exists;
* retrieval through shardd OS processes is bit-identical to the replay
  oracle — including while a replica is being SIGKILL'd mid-query and
  across live-ingest epoch publishes that invalidate shard-local caches.
"""
from __future__ import annotations

import threading
import time

import numpy as np
import pytest

from repro.core import GraphManager, replay
from repro.core.query import parse_attr_options
from repro.data.generators import random_history
from repro.runtime.fault import elastic_replan, rendezvous_rank
from repro.runtime.replica import ReplicaManager
from repro.runtime.rpc import RemoteCallError, RpcConnectionError
from repro.runtime.shard import (InThreadTransport, ProcTransport,
                                 ShardedRetriever, ShardExecutionError)

ATTRS = "+node:all+edge:all"


def _gm(seed: int, P: int, fn: str = "mod_hash", n: int | None = None,
        **kw) -> tuple:
    uni, ev = random_history(n if n is not None else
                             int(np.random.default_rng(seed)
                                 .integers(60, 140)), seed)
    gm = GraphManager(uni, ev, L=16, k=2, cache_bytes=0,
                      prefetch_workers=0, num_partitions=P,
                      partition_fn=fn, **kw)
    return uni, ev, gm


def _times(ev, seed: int, n: int = 5) -> list[int]:
    tmax = int(ev.time[-1]) if len(ev) else 0
    rng = np.random.default_rng(seed + 1)
    return sorted({int(t) for t in rng.integers(0, tmax + 2, n)} | {tmax})


def _check(uni, ev, gm, out, times, attrs=True) -> None:
    opts = parse_attr_options(ATTRS, uni) if attrs else None
    oracle = (gm.dg.get_snapshots(times, opts, pool=gm.pool)
              if attrs else None)
    for t in times:
        truth = replay(uni, ev, t)
        assert np.array_equal(out[t].node_mask, truth.node_mask), t
        assert np.array_equal(out[t].edge_mask, truth.edge_mask), t
        if attrs:
            assert oracle[t].equal(out[t]), t


# ---------------------------------------------------------------------------
# placement: rendezvous ranking and the ReplicaManager
# ---------------------------------------------------------------------------

def test_rendezvous_rank_is_permutation_with_legacy_primary():
    servers = [f"s{i}" for i in range(5)]
    legacy = elastic_replan(32, servers)
    for p in range(32):
        rank = rendezvous_rank(p, servers)
        assert sorted(rank) == sorted(servers)       # a full permutation
        assert rank[0] == legacy[p]                  # rank 0 == old primary


def test_removing_a_server_reorders_nothing_else():
    """Rendezvous is per-server independent: dropping ``dead`` deletes its
    entry from every ranking without permuting the survivors — the
    minimal-reassignment property."""
    servers = [f"s{i}" for i in range(6)]
    for dead in servers:
        rest = [s for s in servers if s != dead]
        for p in range(24):
            full = rendezvous_rank(p, servers)
            assert rendezvous_rank(p, rest) == \
                [s for s in full if s != dead], (p, dead)


def test_replica_manager_candidates_and_minimal_failover():
    servers = [f"s{i}" for i in range(5)]
    rm = ReplicaManager(servers, replicas=3)
    P = 40
    for p in range(P):
        cands = rm.replicas_of(p, servers)
        assert len(cands) == 3 and len(set(cands)) == 3
        assert cands[0] == rm.primary(p, servers)
    before = rm.assignment(P, servers)
    assert sorted(p for ps in before.values() for p in ps) == list(range(P))
    # kill one server: exactly its partitions move, each to its rank-1
    dead = servers[2]
    alive = [s for s in servers if s != dead]
    after = rm.assignment(P, alive)
    owner_b = {p: w for w, ps in before.items() for p in ps}
    owner_a = {p: w for w, ps in after.items() for p in ps}
    for p in range(P):
        if owner_b[p] != dead:
            assert owner_a[p] == owner_b[p], p       # nobody else moved
        else:
            assert owner_a[p] == rm.replicas_of(p, servers)[1], p


def test_route_picks_first_untried_replica():
    servers = [f"s{i}" for i in range(4)]
    rm = ReplicaManager(servers, replicas=3)
    for p in range(16):
        cands = rm.replicas_of(p, servers)
        assert rm.route(p, servers) == cands[0]
        assert rm.route(p, servers, {cands[0]}) == cands[1]
        assert rm.route(p, servers, {cands[0], cands[1]}) == cands[2]
        # every replica tried: fall back to the primary, not a crash
        assert rm.route(p, servers, set(cands)) == cands[0]


# ---------------------------------------------------------------------------
# satellite (a): a hedged attempt must target a distinct candidate server
# ---------------------------------------------------------------------------

class RecordingTransport(InThreadTransport):
    """In-thread transport instrumented with a per-fetch ``(server, keys)``
    log and a one-shot stall on a chosen server — enough to observe *which
    replica* every attempt routed to without any processes."""

    def __init__(self, gm, servers, stall: str | None = None,
                 stall_s: float = 0.25) -> None:
        super().__init__(gm, servers)
        self.log: list[tuple[str, tuple]] = []
        self.stall = stall
        self.stall_s = stall_s

    def fetch(self, server, keys, *, min_epoch=0, deadline_s=None):
        with self._lock:
            self.log.append((server, tuple(keys)))
        if server == self.stall:
            time.sleep(self.stall_s)
        return super().fetch(server, keys, min_epoch=min_epoch,
                             deadline_s=deadline_s)


def test_hedge_routes_to_distinct_replica():
    uni, ev, gm = _gm(41, 6)
    times = _times(ev, 41)
    tr = RecordingTransport(gm, ["s0", "s1"], stall="s0", stall_s=0.3)
    with ShardedRetriever(gm, 2, transport=tr, replicas=2,
                          hedge_frac=1.0, max_hedges=1,
                          hedge_delay_s=0.01) as sr:
        assert set(sr.assignment(gm.dg.P)) == {"s0", "s1"}
        out = sr.retrieve(times, parse_attr_options(ATTRS, uni))
        assert sr.hedges_total >= 1
        # the hedge of the stalled task re-fetched the *same key batches*
        # from the other server — never a duplicate race on s0
        by_keys: dict[tuple, set] = {}
        for server, keys in tr.log:
            by_keys.setdefault(keys, set()).add(server)
        rehedged = [srvs for srvs in by_keys.values() if len(srvs) > 1]
        assert rehedged, "hedge never issued (or raced the same server)"
        assert all(len(s) == 2 for s in rehedged)
        assert sr.failovers_total >= 1        # the duplicate left its owner
    _check(uni, ev, gm, out, times)
    gm.close()


class UnreachableServerTransport(InThreadTransport):
    """In-thread transport where one server's fetches always fail with a
    retryable connection error — the unreachable-replica model."""

    def __init__(self, gm, servers, dead: str) -> None:
        super().__init__(gm, servers)
        self.dead = dead
        self.dead_hits = 0

    def fetch(self, server, keys, *, min_epoch=0, deadline_s=None):
        if server == self.dead:
            with self._lock:
                self.dead_hits += 1
            raise RpcConnectionError(f"injected: {server} unreachable")
        return super().fetch(server, keys, min_epoch=min_epoch,
                             deadline_s=deadline_s)


def test_inner_retry_fails_over_to_distinct_replica():
    """``fault.retry``'s inner attempts re-plan around the server whose
    fetch failed (attempt-local tried set): with ``task_retries=0`` the
    *only* path to success is an inner retry routed to the other replica.
    Previously every inner attempt re-planned the identical route and
    hammered the same unreachable server through the backoff schedule."""
    uni, ev, gm = _gm(42, 6)
    times = _times(ev, 42)
    tr = UnreachableServerTransport(gm, ["s0", "s1"], dead="s0")
    with ShardedRetriever(gm, 2, transport=tr, replicas=2,
                          task_retries=0, io_retries=2, max_hedges=0,
                          hedge_delay_s=0.0) as sr:
        out = sr.retrieve(times, parse_attr_options(ATTRS, uni))
        assert tr.dead_hits == 1          # failed once, never hammered
        assert sr.requeues_total == 0     # recovered inside the attempt
    _check(uni, ev, gm, out, times)
    gm.close()


# ---------------------------------------------------------------------------
# process transport: bit-identity across (partitioner x P x W x R)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("fn,P,W,R", [("mod_hash", 4, 2, 2),
                                      ("mod_hash", 5, 3, 2),
                                      ("word_cyclic", 6, 3, 3)])
def test_proc_transport_bit_identical(fn, P, W, R):
    uni, ev, gm = _gm(51, P, fn)
    times = _times(ev, 51)
    with ShardedRetriever(gm, W, transport="proc", replicas=R,
                          hedge_delay_s=0.05) as sr:
        out = sr.retrieve(times, parse_attr_options(ATTRS, uni))
        assert sr.last_stats["transport"] == "proc"
        assert sr.last_stats["replicas"] == R
    _check(uni, ev, gm, out, times)
    gm.close()


def test_proc_enable_sharding_env_wiring(monkeypatch):
    """``REPRO_SHARD_TRANSPORT=proc`` / ``REPRO_REPLICAS`` select the
    process transport through ``GraphManager.enable_sharding`` with no
    code changes at the call site — the CI differential hook."""
    monkeypatch.setenv("REPRO_SHARD_TRANSPORT", "proc")
    monkeypatch.setenv("REPRO_REPLICAS", "2")
    uni, ev, gm = _gm(52, 4)
    times = _times(ev, 52, 3)
    gm.enable_sharding(2)
    assert isinstance(gm.sharded.transport, ProcTransport)
    assert gm.sharded.replicas == 2
    out = gm.get_snapshots(times)
    for t in times:
        truth = replay(uni, ev, t)
        assert np.array_equal(out[t].node_mask, truth.node_mask)
        assert np.array_equal(out[t].edge_mask, truth.edge_mask)
    gm.disable_sharding()
    gm.close()


# ---------------------------------------------------------------------------
# chaos: SIGKILL a replica
# ---------------------------------------------------------------------------

@pytest.mark.slow
def test_sigkill_mid_query_fails_over_to_replica():
    uni, ev, gm = _gm(61, 6, n=120)
    times = _times(ev, 61)
    # max_hedges=0: the recovery must go through the requeue/failover path
    # (a hedge racing ahead would also succeed, but nondeterministically)
    with ShardedRetriever(gm, 2, transport="proc", replicas=2,
                          task_retries=2, io_retries=2, max_hedges=0,
                          hedge_delay_s=0.0) as sr:
        victim = next(iter(sr.assignment(gm.dg.P)))
        # every fetch on the victim stalls, so the query is guaranteed to
        # be in-flight against it when the SIGKILL lands
        sr.transport.inject_delay(victim, ms=400.0, count=-1)
        killer = threading.Timer(0.1,
                                 lambda: sr.transport.kill(victim))
        killer.start()
        try:
            out = sr.retrieve(times, parse_attr_options(ATTRS, uni))
        finally:
            killer.join()
        assert victim not in sr.alive_workers()
        assert sr.failovers_total >= 1
    _check(uni, ev, gm, out, times)
    gm.close()


@pytest.mark.slow
def test_sigkill_at_idle_is_excluded_by_heartbeat():
    uni, ev, gm = _gm(62, 6, n=120)
    times = _times(ev, 62)
    with ShardedRetriever(gm, 2, transport="proc", replicas=2,
                          hedge_delay_s=0.05) as sr:
        out1 = sr.retrieve(times, parse_attr_options(ATTRS, uni))
        _check(uni, ev, gm, out1, times)
        victim = next(iter(sr.assignment(gm.dg.P)))
        sr.transport.kill(victim)
        # the heartbeat-RPC probe at query entry detects the corpse
        sr.probe_health(force=True)
        assert victim not in sr.alive_workers()
        r0 = sr.requeues_total
        out2 = sr.retrieve(times, parse_attr_options(ATTRS, uni))
        # excluded *before* routing: no fetch ever hit the dead server
        assert sr.requeues_total == r0
        assert victim not in sr.assignment(gm.dg.P)
    _check(uni, ev, gm, out2, times)
    gm.close()


# ---------------------------------------------------------------------------
# epoch publish invalidates shard-local caches under live ingest
# ---------------------------------------------------------------------------

@pytest.mark.slow
def test_epoch_publish_invalidates_shard_caches():
    uni, ev = random_history(160, 71)
    cut = len(ev) - 40
    gm = GraphManager(uni, ev[:cut], L=16, k=2, cache_bytes=0,
                      prefetch_workers=0, num_partitions=4,
                      partition_fn="mod_hash")
    with ShardedRetriever(gm, 2, transport="proc", replicas=2,
                          hedge_delay_s=0.05) as sr:
        tr = sr.transport
        t_old = int(ev.time[cut - 1])
        out1 = sr.retrieve([t_old])
        truth_old = replay(uni, ev[:cut], t_old)
        assert np.array_equal(out1[t_old].node_mask, truth_old.node_mask)
        # shard caches are warm now; snapshot their invalidation counters
        # (pooled daemons carry counters across owners, so compare deltas)
        before = {s: tr.server_stats(s) for s in tr.servers()}
        assert any(st["hot_bytes_used"] > 0 or st["keys"] > 0
                   for st in before.values())

        gm.update(ev[cut:])                  # commits + publishes an epoch

        after = {s: tr.server_stats(s) for s in tr.servers()}
        for s in tr.servers():
            assert (after[s]["invalidations"]
                    > before[s]["invalidations"]), \
                f"{s} missed the epoch announcement"
            assert after[s]["epoch"] > before[s]["epoch"]
        # post-publish reads are served fresh — bit-identical to a replay
        # of the *full* history, including at the old (overwritten) time
        times = sorted({t_old, int(ev.time[-1])})
        out2 = sr.retrieve(times)
        for t in times:
            truth = replay(uni, ev, t)
            assert np.array_equal(out2[t].node_mask, truth.node_mask), t
            assert np.array_equal(out2[t].edge_mask, truth.edge_mask), t
    gm.close()


# ---------------------------------------------------------------------------
# satellite (b): worker-side exceptions carry the remote traceback
# ---------------------------------------------------------------------------

def test_unowned_fetch_rejection_carries_remote_traceback():
    """At the raw RPC level an unowned fetch is a fatal rejection whose
    error frame carries the worker-side traceback (the transport layers
    its widen-and-retry recovery on top of exactly this signal)."""
    from repro.launch.shardd import _encode_keys
    uni, ev, gm = _gm(81, 4)
    tr = ProcTransport(gm, 2, replicas=1)
    try:
        h = tr._by_name[tr.servers()[0]]
        with pytest.raises(RemoteCallError) as ei:
            h.client.call("fetch", {"k": _encode_keys([(999, 0, "s")]),
                                    "min_epoch": 0})
        e = ei.value
        assert e.retryable is False          # routing gap, not transient
        assert e.remote_type == "ValueError"
        assert "unowned partition" in str(e)
        assert "h_fetch" in e.remote_traceback   # the *worker-side* frame
    finally:
        tr.close()
        gm.close()


def test_unowned_fetch_widens_ownership_and_recovers():
    """A fetch routed beyond a server's configured rendezvous ranks (the
    >1-failure scenario) must not read as a dead server: the transport
    widens the shardd's owned set via ``set_owned`` (cache kept) and
    retries, so the healthy server serves the partition from then on."""
    uni, ev, gm = _gm(83, 6)
    tr = ProcTransport(gm, 3, replicas=1)    # depth 2 of 3: one outsider/p
    try:
        key = next(iter(gm.store.keys()))
        p = key[0]
        outsider = next(s for s in tr.servers() if p not in tr._owned[s])
        want = gm.store.get(key)
        assert tr.fetch(outsider, [key]) == [want]
        assert p in tr._owned[outsider]      # widened, not blacklisted
        # and again, without tripping the rejection path a second time
        assert tr.fetch(outsider, [key]) == [want]
        # a fetch for a partition absent from the store recovers the same
        # way and reports the hole as None (mget_optional protocol)
        assert tr.fetch(outsider, [(999, 0, "s")]) == [None]
    finally:
        tr.close()
        gm.close()


@pytest.mark.slow
def test_shard_execution_error_embeds_remote_traceback():
    import socket
    uni, ev, gm = _gm(82, 4)
    times = _times(ev, 82, 3)
    # a port that refuses connections: bind one, note it, close it
    s = socket.socket()
    s.bind(("127.0.0.1", 0))
    dead_port = s.getsockname()[1]
    s.close()
    with ShardedRetriever(gm, 2, transport="proc", replicas=1,
                          task_retries=0, io_retries=2, max_hedges=0,
                          hedge_delay_s=0.0) as sr:
        tr = sr.transport
        # sabotage: every server's origin points at a closed port, so
        # every fetch fails *inside a worker process* with a connection
        # error and no replica can recover the query
        for h in tr._by_name.values():
            h.client.call("configure", {
                "origin_host": "127.0.0.1", "origin_port": dead_port,
                "owned": None, "epoch": 0})
        with pytest.raises(ShardExecutionError) as ei:
            sr.retrieve(times)
        assert "remote traceback" in str(ei.value)
        assert "h_fetch" in str(ei.value)    # the worker-side frame
        assert isinstance(ei.value.__cause__, RemoteCallError)
    gm.close()


# ---------------------------------------------------------------------------
# satellite (e) support: no process/fd leaks past close()
# ---------------------------------------------------------------------------

def test_close_reaps_processes_when_pooling_disabled(monkeypatch):
    import os
    monkeypatch.setenv("REPRO_SHARDD_POOL", "0")
    uni, ev, gm = _gm(91, 4)
    tr = ProcTransport(gm, 2, replicas=2)
    handles = list(tr._by_name.values())
    pids = [h.pid for h in handles]
    assert all(h.alive() for h in handles)
    tr.close()
    for h, pid in zip(handles, pids):
        assert h.proc.poll() is not None, pid   # exited and reaped
    gm.close()
