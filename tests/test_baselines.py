"""Interval tree / Copy+Log / Log baselines must agree with the oracle."""
import numpy as np
import pytest

from repro.core.baselines import CopyLogStore, IntervalTreeStore, LogStore
from repro.core.events import replay
from repro.data.generators import churn_network, growing_network

RNG = np.random.default_rng(3)


@pytest.mark.parametrize("gen,seed", [(churn_network, 5), (growing_network, 7)])
def test_interval_tree_matches_oracle(gen, seed):
    if gen is churn_network:
        uni, ev = gen(n_initial_edges=120, n_events=900, seed=seed)
    else:
        uni, ev = gen(n_events=900, seed=seed)
    it = IntervalTreeStore(uni, ev)
    tmax = int(ev.time[-1])
    for t in [-1, 0, tmax] + [int(x) for x in RNG.integers(0, tmax, 10)]:
        truth = replay(uni, ev, t)
        got = it.get_snapshot(t)
        assert np.array_equal(got.node_mask, truth.node_mask), t
        assert np.array_equal(got.edge_mask, truth.edge_mask), t


def test_copylog_matches_oracle(churn):
    uni, ev = churn
    cl = CopyLogStore(uni, ev, L=100)
    tmax = int(ev.time[-1])
    for t in [-1, 0, tmax] + [int(x) for x in RNG.integers(0, tmax, 10)]:
        truth = replay(uni, ev, t)
        got = cl.get_snapshot(t)
        assert np.array_equal(got.node_mask, truth.node_mask), t
        assert np.array_equal(got.edge_mask, truth.edge_mask), t


def test_log_store(churn):
    uni, ev = churn
    lg = LogStore(uni, ev)
    t = int(ev.time[500])
    got = lg.get_snapshot(t)
    truth = replay(uni, ev, t)
    assert np.array_equal(got.edge_mask, truth.edge_mask)
