"""GraphQuery documents: validation, the fluent builder, JSON round-trip
(deterministic + hypothesis), the typed error taxonomy, and the HistGraph
context-manager lifecycle."""
import json

import numpy as np
import pytest

from repro.api import (DocumentError, GraphQuery, Q, QueryError,
                       TimeExpressionError, UnknownAttributeError)
from repro.core import GraphManager
from repro.core.errors import AttrOptionsError
from repro.core.events import GraphHistoryBuilder
from repro.core.query import TimeExpression, parse_attr_options


def make_universe():
    b = GraphHistoryBuilder()
    b.add_node(0, 1, attrs={"name": "x", "salary": 10.0, "age": 3.0})
    b.add_node(1, 1)
    b.add_edge(0, 1, 2, attrs={"weight": 1.0, "label": "e"})
    return b.finalize()[0]


# ---------------------------------------------------------------------------
# document validation + builder
# ---------------------------------------------------------------------------


def test_builder_kinds():
    assert Q.at(5).build() == GraphQuery(kind="snapshot", t=5)
    assert Q.at(5, 9).build().kind == "multipoint"
    assert Q.at([5, 9, 9]).build().times == (5, 9)   # dedup, order kept
    d = Q.expr("t0 & ~t1", [3, 7]).attrs("+node:all").build()
    assert (d.kind, d.expr, d.times, d.attrs) == ("expr", "t0 & ~t1",
                                                  (3, 7), "+node:all")
    d = Q.between(10, 20).build()
    assert (d.kind, d.ts, d.te) == ("interval", 10, 20)
    d = Q.between(10, 20).compute("pagerank", damping=0.9).build()
    assert d.kind == "evolve" and d.op == "pagerank"
    assert d.op_kwargs == {"damping": 0.9}
    assert d.times[0] == 10 and d.times[-1] == 20 and len(d.times) <= 32
    # explicit sampling
    assert Q.between(0, 9).step(3).compute("degree").build().times == \
        (0, 3, 6, 9)
    assert len(Q.between(0, 100).points(5).compute("degree").build().times) == 5
    # snapshot builder upgraded by compute
    d = Q.at(5).compute("density").build()
    assert d.kind == "evolve" and d.times == (5,)
    # consistency / reply hints
    d = Q.at(5).fresh().full().use_current(False).build()
    assert d.no_cache and d.reply == "full" and not d.use_current


@pytest.mark.parametrize("bad, field", [
    (dict(kind="nope"), "kind"),
    (dict(kind="snapshot"), "t"),
    (dict(kind="snapshot", t=1, times=[2]), "times"),
    (dict(kind="multipoint"), "times"),
    (dict(kind="multipoint", times=[]), "times"),
    (dict(kind="expr", times=[1, 2]), "expr"),
    (dict(kind="interval", ts=3), "te"),
    (dict(kind="evolve", times=[1], op="masks", reply="huge"), "reply"),
    (dict(kind="snapshot", t=1, op_kwargs={"x": 1}), "op_kwargs"),
    (dict(kind="snapshot", t=1, incremental=False), "incremental"),
])
def test_document_validation_errors(bad, field):
    with pytest.raises(DocumentError) as ei:
        GraphQuery(**bad).validate()
    assert ei.value.position == field
    assert ei.value.to_dict()["kind"] == "document"


def test_from_dict_strictness():
    with pytest.raises(DocumentError):
        GraphQuery.from_dict({"kind": "snapshot", "t": 1, "bogus": 2})
    with pytest.raises(DocumentError):
        GraphQuery.from_dict({"t": 1})
    with pytest.raises(DocumentError):
        GraphQuery.from_dict({"kind": "snapshot", "t": 1, "v": 99})
    with pytest.raises(DocumentError):
        GraphQuery.from_dict({"kind": "snapshot", "t": "soon"})
    with pytest.raises(DocumentError):
        GraphQuery.from_dict([1, 2])
    with pytest.raises(DocumentError) as ei:
        GraphQuery.from_json("{not json")
    assert ei.value.code == "document"
    # evolve defaults its operator like the legacy entry point
    assert GraphQuery.from_dict({"kind": "evolve", "times": [1]}).op == "masks"


def test_non_serializable_programmatic_documents():
    uni = make_universe()
    opts = parse_attr_options("+node:age", uni)
    doc = GraphQuery(kind="snapshot", t=1, attrs=opts)
    with pytest.raises(DocumentError):
        doc.to_dict()
    from repro.core.temporal import PageRankOp
    doc = GraphQuery(kind="evolve", times=(1,), op=PageRankOp())
    with pytest.raises(DocumentError):
        doc.to_dict()


# ---------------------------------------------------------------------------
# JSON round-trip
# ---------------------------------------------------------------------------

_DOCS = [
    Q.at(5).build(),
    Q.at(5).attrs("+node:all-node:salary").fresh().full().build(),
    Q.at(3, 1, 4, 1, 5).use_current(False).build(),
    Q.expr("(t0 & ~t1) | t2", [10, 20, 30]).build(),
    Q.between(0, 1000).build(),
    Q.between(0, 90).step(30).compute("pagerank", damping=0.9,
                                      tol=1e-4).build(),
    Q.evolve([7, 11], "components").attrs("+edge:all").build(),
]


@pytest.mark.parametrize("doc", _DOCS, ids=lambda d: d.kind)
def test_json_roundtrip(doc):
    wire = doc.to_json()
    back = GraphQuery.from_json(wire)
    assert back == doc
    assert back.to_json() == wire            # canonical form is a fixpoint
    json.loads(wire)                          # valid JSON


def test_roundtrip_drops_defaults():
    d = json.loads(Q.at(5).build().to_json())
    assert set(d) == {"v", "kind", "t"}


# -- generative round-trip (hypothesis) -------------------------------------

try:
    from hypothesis import given, settings
    from hypothesis import strategies as st
    _HAVE_HYPOTHESIS = True
except ImportError:
    _HAVE_HYPOTHESIS = False

if _HAVE_HYPOTHESIS:
    _times = st.lists(st.integers(0, 10**6), min_size=1, max_size=6)
    _attrs = st.sampled_from(["", "+node:all", "+edge:all",
                              "+node:all-node:salary+edge:weight"])

    def _tree(n):
        return st.recursive(
            st.tuples(st.just("t"), st.integers(0, n - 1)),
            lambda kids: st.one_of(
                st.tuples(st.just("not"), kids),
                st.tuples(st.just("and"), kids, kids),
                st.tuples(st.just("or"), kids, kids)),
            max_leaves=8)

    @st.composite
    def _docs(draw):
        kind = draw(st.sampled_from(
            ("snapshot", "multipoint", "expr", "interval", "evolve")))
        common = dict(attrs=draw(_attrs),
                      use_current=draw(st.booleans()),
                      no_cache=draw(st.booleans()),
                      reply=draw(st.sampled_from(("summary", "full"))))
        if kind == "snapshot":
            return GraphQuery(kind=kind, t=draw(st.integers(0, 10**6)),
                              **common)
        if kind == "interval":
            return GraphQuery(kind=kind, ts=draw(st.integers(0, 10**6)),
                              te=draw(st.integers(0, 10**6)), **common)
        times = tuple(draw(_times))
        if kind == "multipoint":
            return GraphQuery(kind=kind, times=times, **common)
        if kind == "expr":
            tex = TimeExpression(list(times),
                                 draw(_tree(len(times))))
            return GraphQuery(kind=kind, expr=tex.to_infix(), times=times,
                              **common)
        return GraphQuery(kind=kind, times=times,
                          op=draw(st.sampled_from(
                              ("masks", "degree", "density", "pagerank",
                               "components"))),
                          op_kwargs=draw(st.sampled_from(
                              ({}, {"damping": 0.9}))),
                          incremental=draw(st.booleans()), **common)

    @settings(max_examples=200, deadline=None)
    @given(doc=_docs())
    def test_json_roundtrip_hypothesis(doc):
        back = GraphQuery.from_json(doc.to_json())
        assert back == doc
        assert back.to_json() == doc.to_json()
        if doc.kind == "expr":   # TimeExpression survives the infix trip
            assert back.time_expression().expr == doc.time_expression().expr


# ---------------------------------------------------------------------------
# typed error taxonomy
# ---------------------------------------------------------------------------


def test_attr_errors_are_typed_and_positioned():
    uni = make_universe()
    with pytest.raises(UnknownAttributeError) as ei:
        parse_attr_options("+node:all+edge:nope", uni)
    err = ei.value
    assert isinstance(err, (QueryError, KeyError))
    assert err.position == len("+node:all+edge:")
    assert str(err) == "unknown edge attribute 'nope'"   # no KeyError quoting
    assert err.to_dict() == {"kind": "unknown-attribute",
                             "message": "unknown edge attribute 'nope'",
                             "position": 15}
    with pytest.raises(AttrOptionsError) as ei:
        parse_attr_options("+node:all junk", uni)
    assert isinstance(ei.value, ValueError)
    assert ei.value.position == 9            # spaces survive in attr specs


def test_time_expression_errors_are_typed_and_positioned():
    with pytest.raises(TimeExpressionError) as ei:
        TimeExpression.parse("t0 & #", [1, 2])
    assert isinstance(ei.value, ValueError)
    assert ei.value.position == 3            # de-spaced offset of '#'
    with pytest.raises(TimeExpressionError) as ei:
        TimeExpression.parse("(t0", [1])
    assert ei.value.position == 3            # end of input
    with pytest.raises(TimeExpressionError) as ei:
        TimeExpression.parse("t0 & t9", [1, 2])
    assert ei.value.position == 3
    assert ei.value.to_dict()["kind"] == "time-expression"


def test_unknown_operator_is_typed():
    from repro.core.errors import UnknownOperatorError
    from repro.core.temporal import resolve_op
    with pytest.raises(UnknownOperatorError):
        resolve_op("no-such-op", {})


# ---------------------------------------------------------------------------
# HistGraph context manager + pool reclamation
# ---------------------------------------------------------------------------


def test_hist_graph_context_manager(churn):
    uni, ev = churn
    gm = GraphManager(uni, ev, L=100, k=2, cache_bytes=0)
    t = int(ev.time[600])
    before = gm.pool.num_active()
    with gm.get_hist_graph(t) as h:
        gid = h.gid
        assert gm.pool.num_active() == before + 1
        n = h.num_nodes()
        assert n > 0
    # exit released the bit pair and the cleaner reclaimed the row
    assert gid not in gm.pool.table
    assert gm.pool.num_active() == before
    free_before = len(gm.pool._free_bits)
    with gm.get_hist_graph(t) as h2:
        # the recycled row is reused, not grown
        assert len(gm.pool._free_bits) == free_before - 2
    h2.close()                                # double close is a no-op
    assert gm.pool.num_active() == before
    # expr HistGraphs participate in the same lifecycle
    tex = TimeExpression.parse("t0 | t1",
                               [int(ev.time[300]), int(ev.time[900])])
    with gm.get_hist_graph_expr(tex) as g:
        st = g.to_state()
        assert st.node_mask.sum() == g.num_nodes()
    assert g.gid not in gm.pool.table
    gm.close()
