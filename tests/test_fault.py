"""Regression tests for the fault layer (`runtime/fault.py`) — the bugs
these pin down were dormant until the sharded retriever started driving
the layer on every query:

* ``retry(attempts=0)`` used to raise ``UnboundLocalError`` (raising an
  unbound ``last``) instead of rejecting the nonsensical bound;
* ``KeyError`` used to be in the default retryable set, turning every
  missing-blob routing bug into a multi-attempt backoff stall;
* ``StragglerMitigator.assign`` used to hand the *same* outstanding task
  to every idle worker, unboundedly — N idle workers would all duplicate
  one fetch.
"""
from __future__ import annotations

import traceback

import pytest

from repro.runtime.fault import (FetchTask, HeartbeatTracker,
                                 StragglerMitigator, elastic_replan, retry)


# ---------------------------------------------------------------------------
# retry
# ---------------------------------------------------------------------------

def test_retry_rejects_nonpositive_attempts():
    with pytest.raises(ValueError, match="attempts"):
        retry(lambda: 1, attempts=0)
    with pytest.raises(ValueError, match="attempts"):
        retry(lambda: 1, attempts=-3)


def test_retry_keyerror_not_retried():
    calls = []

    def fn():
        calls.append(1)
        raise KeyError("missing blob")

    with pytest.raises(KeyError):
        retry(fn, attempts=5, sleep=lambda s: None)
    assert len(calls) == 1, "a routing bug must fail fast, not back off"


def test_retry_backoff_and_success():
    sleeps = []
    calls = []

    def fn():
        calls.append(1)
        if len(calls) < 3:
            raise IOError("transient")
        return "ok"

    assert retry(fn, attempts=4, base_delay=0.01,
                 sleep=sleeps.append) == "ok"
    assert len(calls) == 3
    assert sleeps == [0.01, 0.02]     # exponential, none after success


def test_retry_exhaustion_preserves_traceback():
    def inner():
        raise TimeoutError("store timed out")

    sleeps = []
    with pytest.raises(TimeoutError) as ei:
        retry(inner, attempts=3, sleep=sleeps.append)
    # 3 attempts -> 2 backoffs; the re-raise keeps the original frame
    assert len(sleeps) == 2
    frames = traceback.extract_tb(ei.value.__traceback__)
    assert any(f.name == "inner" for f in frames)


# ---------------------------------------------------------------------------
# StragglerMitigator hedging
# ---------------------------------------------------------------------------

def _drain_fresh(sm):
    """Assign until only hedges remain; returns the fresh keys."""
    out = []
    while sm.remaining():
        out.append(sm.assign().key)
    return out


def test_hedge_duplicates_bounded_per_task():
    # 3 outstanding tasks, 10 idle workers: each task may be duplicated
    # at most once, and different idle workers hedge *different* tasks
    tasks = [FetchTask(p, f"k{p}", size_est=1) for p in range(3)]
    sm = StragglerMitigator(tasks, hedge_frac=1.0, max_duplicates=1)
    fresh = _drain_fresh(sm)
    assert sorted(fresh) == ["k0", "k1", "k2"]
    hedged = []
    for _ in range(10):                    # 10 idle workers ask for work
        t = sm.assign()
        if t is not None:
            hedged.append(t.key)
    assert sorted(hedged) == ["k0", "k1", "k2"], \
        "idle workers must spread hedges across tasks, one dup each"
    assert sm.duplicates == 3


def test_hedge_prefers_oldest_assigned():
    tasks = [FetchTask(p, f"k{p}", size_est=1) for p in range(3)]
    sm = StragglerMitigator(tasks, hedge_frac=1.0, max_duplicates=2)
    order = _drain_fresh(sm)
    # first hedge goes to the longest-outstanding (first-assigned) task
    assert sm.assign().key == order[0]
    sm.complete(order[0])
    assert sm.assign().key == order[1]


def test_hedge_zero_duplicates_disables_hedging():
    tasks = [FetchTask(0, "k0", size_est=1)]
    sm = StragglerMitigator(tasks, hedge_frac=1.0, max_duplicates=0)
    assert sm.assign().key == "k0"
    assert sm.assign() is None
    assert sm.duplicates == 0


def test_complete_first_wins_and_fail_requeues():
    tasks = [FetchTask(0, "k0", size_est=1), FetchTask(1, "k1", size_est=1)]
    sm = StragglerMitigator(tasks, hedge_frac=0.0)
    a = sm.assign()
    assert sm.complete(a.key) is True
    assert sm.complete(a.key) is False     # hedge finishing second
    b = sm.assign()
    assert sm.fail(b.key) is True          # requeued for a survivor
    assert not sm.finished()
    b2 = sm.assign()
    assert b2.key == b.key
    assert sm.complete(b2.key) is True
    assert sm.fail(b2.key) is False        # already done: no requeue
    assert sm.finished()


# ---------------------------------------------------------------------------
# elastic replan stability
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("n_workers", [3, 5, 9])
def test_elastic_replan_moves_only_dead_workers_partitions(n_workers):
    workers = [f"w{i}" for i in range(n_workers)]
    before = elastic_replan(64, workers)
    for dead in workers:
        survivors = [w for w in workers if w != dead]
        after = elastic_replan(64, survivors)
        assert set(after) == set(range(64))
        assert dead not in after.values()
        for p, w in before.items():
            if w != dead:
                # consistent hashing: survivors keep their partitions
                assert after[p] == w, (dead, p)


# ---------------------------------------------------------------------------
# heartbeat boundary
# ---------------------------------------------------------------------------

def test_heartbeat_boundary_exactly_timeout():
    clock = [0.0]
    hb = HeartbeatTracker(["a"], timeout=5.0, clock=lambda: clock[0])
    clock[0] = 5.0                     # elapsed == timeout: still alive
    assert hb.alive() == ["a"] and hb.dead() == []
    clock[0] = 5.0 + 1e-9              # just past: dead
    assert hb.alive() == [] and hb.dead() == ["a"]
    hb.beat("a")
    assert hb.alive() == ["a"]
    hb.mark_dead("a")
    assert hb.dead() == ["a"]
    hb.beat("a")                       # a fresh beat revives
    assert hb.alive() == ["a"]
