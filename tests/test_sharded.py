"""Sharded multi-worker retrieval: scatter/execute/gather correctness and
the fault-layer behaviors it activates.

The core property (differenced below against both the unsharded host
path and the brute-force replay oracle): a shard executing the same plan
DAG with its Fetch nodes restricted to its owned storage partitions is
exact on its owned slots, so the gather step's slot-wise union is
**bit-identical** to unsharded execution — masks and attributes, for
every registered partitioner.
"""
from __future__ import annotations

import threading
import time

import numpy as np
import pytest

from repro.core import GraphManager, replay
from repro.core.query import NO_ATTRS, parse_attr_options
from repro.data.generators import random_history
from repro.runtime.partition import get_partitioner
from repro.runtime.shard import ShardedRetriever, ShardExecutionError


def _gm(seed: int, P: int, fn: str = "mod_hash", **kw) -> tuple:
    uni, ev = random_history(int(np.random.default_rng(seed)
                                 .integers(60, 140)), seed)
    gm = GraphManager(uni, ev, L=16, k=2, cache_bytes=0,
                      prefetch_workers=0, num_partitions=P,
                      partition_fn=fn, **kw)
    return uni, ev, gm


def _times(ev, seed: int, n: int = 5) -> list[int]:
    tmax = int(ev.time[-1]) if len(ev) else 0
    rng = np.random.default_rng(seed + 1)
    ts = sorted({int(t) for t in rng.integers(0, tmax + 2, n)} | {tmax})
    return ts


# ---------------------------------------------------------------------------
# differential: sharded == unsharded == replay, masks + attrs
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("fn,P,W", [("mod_hash", 4, 4),
                                    ("mod_hash", 3, 5),
                                    ("word_cyclic", 4, 2)])
def test_sharded_bit_identical(fn, P, W):
    for seed in (3, 11):
        uni, ev, gm = _gm(seed, P, fn)
        opts = parse_attr_options("+node:all+edge:all", uni)
        times = _times(ev, seed)
        with ShardedRetriever(gm, W, hedge_delay_s=0.0) as sr:
            sharded = sr.retrieve(times, opts)
        oracle = gm.dg.get_snapshots(times, opts, pool=gm.pool)
        for t in times:
            truth = replay(uni, ev, t)
            assert np.array_equal(sharded[t].node_mask, truth.node_mask)
            assert np.array_equal(sharded[t].edge_mask, truth.edge_mask)
            assert oracle[t].equal(sharded[t]), (seed, t)
        gm.close()


def test_sharded_through_query_service():
    """``enable_sharding`` routes ``QueryService.retrieve_points`` through
    the shard pool; results and cache behavior stay identical."""
    uni, ev, gm = _gm(5, 4)
    times = _times(ev, 5)
    gm.enable_sharding(4)
    got = gm.get_snapshots(times)
    assert gm.sharded.last_stats["shards"] >= 1
    for t in times:
        truth = replay(uni, ev, t)
        assert np.array_equal(got[t].node_mask, truth.node_mask)
        assert np.array_equal(got[t].edge_mask, truth.edge_mask)
    gm.disable_sharding()
    assert gm.sharded is None
    gm.close()


def test_single_worker_degenerates_to_host_path():
    uni, ev, gm = _gm(9, 4)
    times = _times(ev, 9)
    with ShardedRetriever(gm, 1) as sr:
        out = sr.retrieve(times)
        assert sr.last_stats["shards"] == 1
        assert sr.last_stats["hedges"] == 0
        assert sr.last_stats["requeues"] == 0
        assert sr.last_stats["transport"] == "thread"
    for t in times:
        truth = replay(uni, ev, t)
        assert np.array_equal(out[t].node_mask, truth.node_mask)
    gm.close()


# ---------------------------------------------------------------------------
# plan scattering
# ---------------------------------------------------------------------------

def test_scatter_ir_restricts_fetches_and_splits_cost():
    from repro.core.planir import Fetch, scatter_ir
    uni, ev, gm = _gm(21, 4)
    ir = gm.dg.plan_multipoint(_times(ev, 21), NO_ATTRS, True)
    shards = {"a": (0, 1), "b": (2, 3)}
    out = scatter_ir(ir, shards, total_parts=4)
    assert set(out) == {"a", "b"}
    for shard, parts in shards.items():
        sir = out[shard]
        assert sir.targets == ir.targets
        assert len(sir.nodes) == len(ir.nodes)
        for n in sir.nodes:
            if isinstance(n.op, Fetch):
                assert n.op.parts == parts
        assert np.isclose(sir.total_weight, ir.total_weight / 2)
    gm.close()


def test_scatter_plans_merges_per_shard():
    from repro.api.compiler import scatter_plans
    from repro.core.planir import Fetch
    uni, ev, gm = _gm(22, 4)
    ts = _times(ev, 22, 8)
    cut = len(ts) // 2
    irs = [gm.dg.plan_multipoint(ts[:cut], NO_ATTRS, True),
           gm.dg.plan_multipoint(ts[cut:], NO_ATTRS, True)]
    out = scatter_plans(irs, {"a": (0, 2), "b": (1, 3)}, 4)
    for shard, parts in (("a", (0, 2)), ("b", (1, 3))):
        merged = out[shard]
        assert set(merged.targets) == set(ts)
        for n in merged.nodes:
            if isinstance(n.op, Fetch):
                assert n.op.parts == parts
    gm.close()


# ---------------------------------------------------------------------------
# PartitionedKV routing
# ---------------------------------------------------------------------------

def test_partitioned_kv_routing_matches_registry():
    from repro.storage.kv import MemKV, PartitionedKV
    parts = [MemKV() for _ in range(3)]
    kv = PartitionedKV(parts, partitioner="mod_hash")
    hp = get_partitioner("mod_hash")
    for pid in range(64):
        kv.put((pid, 0, "s"), b"x")
        want = int(hp(np.asarray([pid], np.int64), 3)[0])
        assert (pid, 0, "s") in parts[want], pid
    # default keeps the legacy modulo routing (old stores stay readable)
    legacy_parts = [MemKV() for _ in range(3)]
    legacy = PartitionedKV(legacy_parts)
    for pid in range(16):
        legacy.put((pid, 0, "s"), b"y")
        assert (pid, 0, "s") in legacy_parts[pid % 3]


# ---------------------------------------------------------------------------
# fault behaviors through the retriever
# ---------------------------------------------------------------------------

def test_transient_worker_failure_requeues_and_recovers():
    uni, ev, gm = _gm(31, 6)
    times = _times(ev, 31)
    victim = []
    failed = threading.Event()

    def hook(worker, parts):
        if not victim:
            victim.append(worker)
        if worker == victim[0] and not failed.is_set():
            failed.set()
            raise IOError("injected shard fault")

    with ShardedRetriever(gm, 3, io_retries=1, task_retries=1,
                          hedge_delay_s=0.0, shard_hook=hook) as sr:
        out = sr.retrieve(times)
        assert sr.requeues_total == 1
    for t in times:
        truth = replay(uni, ev, t)
        assert np.array_equal(out[t].node_mask, truth.node_mask)
        assert np.array_equal(out[t].edge_mask, truth.edge_mask)
    gm.close()


def test_permanent_worker_failure_raises_after_retries():
    uni, ev, gm = _gm(32, 8)
    times = _times(ev, 32)
    victim = []

    def hook(worker, parts):
        if not victim:
            victim.append(worker)
        if worker == victim[0]:
            raise IOError("shard is gone")

    with ShardedRetriever(gm, 3, io_retries=1, task_retries=1,
                          hedge_delay_s=0.0, max_hedges=0,
                          shard_hook=hook) as sr:
        assert len(sr.assignment(gm.dg.P)) > 1
        with pytest.raises(ShardExecutionError):
            sr.retrieve(times)
        # the failed worker reads dead: the next assignment excludes it
        # and moves only its partitions (consistent hashing)
        assert victim[0] not in sr.alive_workers()
        after = sr.assignment(gm.dg.P)
        assert victim[0] not in after
    gm.close()


def test_dead_worker_moves_only_its_partitions():
    uni, ev, gm = _gm(33, 16)
    with ShardedRetriever(gm, ["w0", "w1", "w2", "w3"]) as sr:
        before = sr.assignment(16)
        owner = {p: w for w, ps in before.items() for p in ps}
        dead = next(iter(before))
        sr.heartbeats.mark_dead(dead)
        after = sr.assignment(16)
        assert dead not in after
        assert sorted(p for ps in after.values() for p in ps) == list(range(16))
        for w, ps in after.items():
            for p in ps:
                if owner[p] != dead:
                    assert owner[p] == w, (p, dead)
        # still serves correct results without the dead worker
        times = _times(ev, 33, 3)
        out = sr.retrieve(times)
        for t in times:
            truth = replay(uni, ev, t)
            assert np.array_equal(out[t].node_mask, truth.node_mask)
    gm.close()


def test_hedged_fetch_beats_straggler():
    uni, ev, gm = _gm(34, 6)
    times = _times(ev, 34)
    first = threading.Event()

    def hook(worker, parts):
        # exactly the first attempt overall stalls; the hedge duplicate of
        # the same shard task is a later invocation and runs fast
        if not first.is_set():
            first.set()
            time.sleep(0.25)

    with ShardedRetriever(gm, 3, hedge_frac=1.0, max_hedges=1,
                          hedge_delay_s=0.01, shard_hook=hook) as sr:
        out = sr.retrieve(times)
        assert sr.hedges_total >= 1
    for t in times:
        truth = replay(uni, ev, t)
        assert np.array_equal(out[t].node_mask, truth.node_mask)
    gm.close()
