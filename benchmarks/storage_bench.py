"""BENCH_storage: payload-codec compression ratio, decode throughput, and
cold-vs-hot tiered retrieval.

Three measurements against one churn-network history:

* **codec** — the same index built under the legacy ``raw`` wire format
  and the ``v2`` codec (delta-of-delta/varint/bitpack + zlib behind a
  checksummed header): at-rest store size, per-blob decode MB/s, and the
  retrieval workload's KV bytes read at equal per-get latency (the
  acceptance point: ≥3× fewer bytes at ±10% p50).
* **tiered** — the v2 store re-homed onto a disk-resident
  ``TieredKV(LogFileKV)`` whose hot-tier budget is a quarter of the store
  (a genuinely disk-bound run), driven by the same workload and
  spot-checked against the replay oracle.

All retrieval engines run against the same store wrapped with a simulated
remote round-trip latency (MemKV alone is nanoseconds and would hide the
fetch economics).  Emits rows in the run.py contract and writes
``BENCH_storage.json``.  Run standalone::

    PYTHONPATH=src python -m benchmarks.storage_bench --quick
"""
from __future__ import annotations

import json
import time

import numpy as np

from repro.core import GraphManager, replay
from repro.core.query import NO_ATTRS
from repro.data.generators import churn_network
from repro.runtime.executor import Prefetcher
from repro.storage import codec as codec_mod
from repro.storage.kv import LogFileKV, MemKV, TieredKV

from .retrieval_bench import LatencyKV

OUT_JSON = "BENCH_storage.json"
CONCURRENCY = 16
GET_LATENCY_US = 120.0
WIRE_MB_S = 100.0          # simulated store bandwidth (cross-AZ / SSD class)


class ByteLatencyKV(LatencyKV):
    """Per-get RTT *plus* a bytes/bandwidth transfer term — a fixed RTT
    alone would never reward moving fewer bytes, which is the entire
    economics this bench measures."""

    def __init__(self, inner, get_latency_s: float, mb_per_s: float) -> None:
        super().__init__(inner, get_latency_s)
        self.byte_s = 1.0 / (mb_per_s * 2**20)

    def get(self, key):
        v = self.inner.get(key)
        time.sleep(self.lat + len(v) * self.byte_s)
        self.stats.add_get(len(v))
        return v


def _batches(tmax: int, n_batches: int, seed: int = 0) -> list[list[int]]:
    rng = np.random.default_rng(seed)
    return [[int(t) for t in rng.integers(0, tmax + 1, CONCURRENCY)]
            for _ in range(n_batches)]


def _run_workload(gm, store, batches, reps: int = 3) -> dict:
    pf = Prefetcher(store, workers=8)
    # steady-state measurement: one untimed pass warms the decoded-payload
    # cache (and, for tiered stores, the hot tier) the way a serving
    # process is warm after its first seconds of traffic; best-of-``reps``
    # because time.sleep-based latency simulation quantizes coarsely on
    # some kernels and a single rep's p50 is noisy
    gm.dg.get_snapshots(batches[0], NO_ATTRS, pool=gm.pool, prefetch=pf)
    p50s = []
    gets = bytes_read = None
    for _ in range(reps):
        store.stats.reset()
        lat = []
        for batch in batches:
            t0 = time.perf_counter()
            gm.dg.get_snapshots(batch, NO_ATTRS, pool=gm.pool, prefetch=pf)
            lat.append((time.perf_counter() - t0) / len(batch))
        p50s.append(float(np.percentile(lat, 50) * 1e6))
        if gets is None:
            gets, bytes_read = store.stats.gets, store.stats.bytes_read
    pf.close()
    return {"p50_us_per_q": min(p50s),
            "p50_reps_us_per_q": [round(x, 1) for x in p50s],
            "kv_gets": gets,
            "kv_bytes_read": bytes_read}


def bench_storage(quick: bool = False):
    n = 8_000 if quick else 24_000
    n_batches = 4 if quick else 10
    uni, ev = churn_network(n_initial_edges=n // 12, n_events=n, seed=7)
    # paper-scale leaves (L in the hundreds): payload economics, not
    # skeleton-topology economics, are what this bench measures
    L = max(n // 16, 250)
    tmax = int(ev.time[-1])
    batches = _batches(tmax, n_batches, seed=3)

    report: dict = {"n_events": n, "concurrency": CONCURRENCY,
                    "n_batches": n_batches,
                    "kv_get_latency_us": GET_LATENCY_US,
                    "wire_mb_per_s": WIRE_MB_S, "codecs": {}}
    rows = []

    for codec_name in ("raw", "v2"):
        with codec_mod.using_codec(codec_name):
            inner = MemKV()
            store = ByteLatencyKV(inner, GET_LATENCY_US * 1e-6, WIRE_MB_S)
            gm = GraphManager(uni, ev, store=store, L=L, k=2,
                              diff_fn="intersection", cache_bytes=0)
            sk = gm.dg.skeleton_stats()
            # decode throughput: logical MB decoded per wall second over
            # every blob in the store
            blobs = [inner._d[k] for k in inner._d]
            t0 = time.perf_counter()
            logical = 0
            for b in blobs:
                logical += sum(int(a.nbytes)
                               for a in codec_mod.decode_blob(b).values())
            dt = time.perf_counter() - t0
            res = _run_workload(gm, store, batches)
            res.update({
                "store_bytes": inner.total_bytes(),
                "logical_bytes": sk["total_bytes"],
                "compression_ratio": round(sk["compression_ratio"], 3),
                "decode_mb_per_s": round(logical / 2**20 / max(dt, 1e-9), 1),
            })
            report["codecs"][codec_name] = res
            rows.append((f"storage/codec_{codec_name}", res["p50_us_per_q"],
                         dict(res)))
            gm.close()

    raw = report["codecs"]["raw"]
    v2 = report["codecs"]["v2"]
    report["kv_bytes_read_ratio"] = round(
        raw["kv_bytes_read"] / max(v2["kv_bytes_read"], 1), 3)
    report["store_bytes_ratio"] = round(
        raw["store_bytes"] / max(v2["store_bytes"], 1), 3)
    report["p50_latency_ratio_v2_vs_raw"] = round(
        v2["p50_us_per_q"] / max(raw["p50_us_per_q"], 1e-9), 3)

    # ---- disk-resident tiered run (v2) ------------------------------------
    with codec_mod.using_codec("v2"):
        import tempfile
        d = tempfile.mkdtemp(prefix="repro-storage-bench-")
        cold = LogFileKV(d)
        tiered = TieredKV(cold, hot_bytes=1 << 30)
        store = ByteLatencyKV(tiered, GET_LATENCY_US * 1e-6, WIRE_MB_S)
        gm = GraphManager(uni, ev, store=store, L=L, k=2,
                          diff_fn="intersection", cache_bytes=0)
        store_bytes = cold._log_size
        hot_budget = max(store_bytes // 4, 1)
        tiered.resize_hot(hot_budget)   # store strictly exceeds the hot tier
        cold.stats.reset()
        tiered.stats.reset()
        res = _run_workload(gm, store, batches)
        # oracle spot-check: the disk-resident engine serves exact snapshots
        ok = True
        for t in batches[0][:3]:
            st = gm.dg.get_snapshot(int(t), NO_ATTRS, pool=gm.pool)
            tr = replay(uni, ev, int(t))
            ok &= bool(np.array_equal(st.node_mask, tr.node_mask)
                       and np.array_equal(st.edge_mask, tr.edge_mask))
        res.update({
            "store_bytes": int(store_bytes),
            "hot_budget_bytes": int(hot_budget),
            "disk_resident": bool(store_bytes > hot_budget),
            "hot_hits": tiered.stats.hot_hits,
            "hot_misses": tiered.stats.hot_misses,
            "cold_gets": cold.stats.gets,
            "evictions": tiered.evictions,
            "oracle_ok": ok,
        })
        report["tiered"] = res
        rows.append(("storage/tiered_disk", res["p50_us_per_q"], dict(res)))
        gm.close()
        tiered.close()              # flush the disk tier (gm doesn't own it)

    with open(OUT_JSON, "w") as f:
        json.dump(report, f, indent=2)
    rows.append(("storage/report", 0.0, {"json": OUT_JSON}))
    return rows


def main() -> None:
    import argparse
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true")
    args = ap.parse_args()
    print("name,us_per_call,derived")
    for name, us, derived in bench_storage(quick=args.quick):
        print(f"{name},{us:.1f},\"{json.dumps(derived)}\"", flush=True)


if __name__ == "__main__":
    main()
