# One function per paper table/figure. Prints ``name,us_per_call,derived``
# CSV (the stub contract), plus the dry-run roofline report when available.
from __future__ import annotations

import argparse
import json
import sys
import traceback


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true",
                    help="small datasets (CI-sized)")
    ap.add_argument("--only", default=None,
                    help="substring filter on benchmark names")
    ap.add_argument("--dryrun-json", default="dryrun_results.json")
    args = ap.parse_args()

    from . import (ingest_bench, materialize_bench, paper_figs, query_bench,
                   replica_bench, retrieval_bench, roofline_report,
                   server_bench, shard_bench, storage_bench, temporal_bench)

    benches = [
        materialize_bench.bench_materialize,
        retrieval_bench.bench_retrieval,
        roofline_report.bench_device,
        temporal_bench.bench_temporal,
        storage_bench.bench_storage,
        query_bench.bench_query,
        ingest_bench.bench_ingest,
        shard_bench.bench_shard,
        replica_bench.bench_replica,
        server_bench.bench_server,
        paper_figs.fig6_vs_copylog,
        paper_figs.fig7_vs_interval_tree,
        paper_figs.fig8a_graphpool_memory,
        paper_figs.fig8b_partitioned,
        paper_figs.fig8c_multipoint,
        paper_figs.fig8d_columnar,
        paper_figs.fig9_construction_params,
        paper_figs.fig10_materialization,
        paper_figs.fig11_diff_functions,
        paper_figs.bitmap_penalty,
        paper_figs.subgraph_pattern_index,
    ]

    print("name,us_per_call,derived")
    failures = 0
    for bench in benches:
        if args.only and args.only not in bench.__name__:
            continue
        try:
            for name, us, derived in bench(quick=args.quick):
                print(f"{name},{us:.1f},\"{json.dumps(derived)}\"",
                      flush=True)
        except Exception:
            failures += 1
            print(f"{bench.__name__},ERROR,\"{traceback.format_exc(limit=2)}\"",
                  file=sys.stderr, flush=True)
    if args.only is None or "roofline" in (args.only or ""):
        try:
            for name, us, derived in roofline_report.run(args.dryrun_json):
                print(f"{name},{us:.1f},\"{json.dumps(derived)}\"", flush=True)
        except Exception:
            failures += 1
            traceback.print_exc()
    if failures:
        sys.exit(1)


if __name__ == "__main__":
    main()
