"""One benchmark per paper table/figure (§7).  Each returns rows of
(name, us_per_call, derived-metrics-dict)."""
from __future__ import annotations

import time

import numpy as np

from repro.core import GraphManager, replay
from repro.core.baselines import CopyLogStore, IntervalTreeStore, LogStore
from repro.core.query import NO_ATTRS, parse_attr_options
from repro.data.generators import churn_network, growing_network


def _dataset1(n=12_000, n_attrs=0):
    return growing_network(n_events=n, seed=42, n_attrs=n_attrs)


def _dataset2(n=12_000, n_attrs=0):
    return churn_network(n_initial_edges=n // 12, n_events=n, seed=42,
                         p_attr_update=0.05 if n_attrs else 0.0,
                         p_transient=0.01, n_attrs=n_attrs)


# the paper's setting is I/O-bound retrieval from a disk KV store; our KV is
# in-memory, so alongside wall time we report bytes+gets and a modeled disk
# time (5 ms/seek + 100 MB/s — conservative 2012-era numbers)
def _disk_ms(gets: int, bytes_read: int, queries: int) -> float:
    return (gets * 5.0 + bytes_read / 100e6 * 1e3) / max(queries, 1)


def _qtimes(ev, n=25):
    return [int(t) for t in np.linspace(int(ev.time[0]), int(ev.time[-1]), n)]


def _time_queries(fn, times, reps=1):
    t0 = time.perf_counter()
    for _ in range(reps):
        for t in times:
            fn(t)
    dt = time.perf_counter() - t0
    return dt / (len(times) * reps) * 1e6  # µs per query


def fig6_vs_copylog(quick=False):
    """fig 6: DeltaGraph (Intersection & Balanced) vs Copy+Log at matched
    storage budgets, 25 uniform queries, datasets 1 & 2.  Primary metric =
    modeled disk time (the paper's regime); wall time and I/O stats are
    also reported."""
    rows = []
    n = 5_000 if quick else 40_000
    for ds_name, (uni, ev) in (("ds1", _dataset1(n)), ("ds2", _dataset2(n))):
        times = _qtimes(ev)
        cl = CopyLogStore(uni, ev, L=len(ev) // 8)
        cl_bytes = cl.storage_bytes()
        cl.store.stats.reset()
        us = _time_queries(cl.get_snapshot, times)
        rows.append((f"fig6/{ds_name}/copylog", us,
                     {"storage_bytes": cl_bytes,
                      "bytes_read": cl.store.stats.bytes_read,
                      "disk_ms_per_q": round(_disk_ms(
                          cl.store.stats.gets, cl.store.stats.bytes_read,
                          len(times)), 2)}))
        for fn_name in ("intersection", "balanced"):
            # matched storage: pick the smallest L whose index fits the
            # Copy+Log budget (the paper's equal-disk-space protocol)
            gm = None
            for frac in (40, 32, 24, 16, 12, 8):
                cand = GraphManager(uni, ev, L=max(len(ev) // frac, 32), k=4,
                                    diff_fn=fn_name)
                if cand.store.total_bytes() <= cl_bytes or gm is None:
                    gm = cand
                if cand.store.total_bytes() <= cl_bytes:
                    break
            gm.store.stats.reset()
            us = _time_queries(
                lambda t: gm.dg.get_snapshot(t, NO_ATTRS, pool=gm.pool),
                times)
            rows.append((f"fig6/{ds_name}/deltagraph-{fn_name}", us,
                         {"storage_bytes": gm.store.total_bytes(),
                          "bytes_read": gm.store.stats.bytes_read,
                          "disk_ms_per_q": round(_disk_ms(
                              gm.store.stats.gets,
                              gm.store.stats.bytes_read, len(times)), 2)}))
    return rows


def fig7_vs_interval_tree(quick=False):
    """fig 7: interval tree vs DeltaGraph (low materialization & total
    materialization), dataset 2, k=4."""
    n = 4_000 if quick else 12_000
    uni, ev = _dataset2(n)
    times = _qtimes(ev)
    rows = []
    it = IntervalTreeStore(uni, ev)
    rows.append(("fig7/interval_tree", _time_queries(it.get_snapshot, times),
                 {"memory_bytes": it.memory_bytes()}))
    gm = GraphManager(uni, ev, L=max(len(ev) // 40, 64), k=4)
    gm.materialize_roots(depth=2)  # root's grandchildren
    rows.append(("fig7/deltagraph-grandchildren",
                 _time_queries(lambda t: gm.dg.get_snapshot(
                     t, NO_ATTRS, pool=gm.pool), times),
                 {"pool_bytes": gm.pool.memory_bytes()}))
    gm2 = GraphManager(uni, ev, L=max(len(ev) // 40, 64), k=4)
    gm2.total_materialization()
    rows.append(("fig7/deltagraph-total-mat",
                 _time_queries(lambda t: gm2.dg.get_snapshot(
                     t, NO_ATTRS, pool=gm2.pool), times),
                 {"pool_bytes": gm2.pool.memory_bytes()}))
    lg = LogStore(uni, ev)
    rows.append(("fig7/naive-log", _time_queries(lg.get_snapshot, times[:5]),
                 {}))
    return rows


def fig8a_graphpool_memory(quick=False):
    """fig 8a: cumulative GraphPool memory over 100 snapshot retrievals."""
    n = 4_000 if quick else 12_000
    rows = []
    nq = 20 if quick else 100
    for ds_name, (uni, ev) in (("ds1", _dataset1(n)), ("ds2", _dataset2(n))):
        gm = GraphManager(uni, ev, L=max(len(ev) // 40, 64), k=4)
        times = _qtimes(ev, nq)
        t0 = time.perf_counter()
        for t in times:
            gm.get_hist_graph(t)
        dt = (time.perf_counter() - t0) / nq * 1e6
        disjoint = sum(int(replay(uni, ev, t).node_mask.sum()
                           + replay(uni, ev, t).edge_mask.sum()) * 16
                       for t in times[:: max(nq // 10, 1)]) * max(nq // 10, 1)
        rows.append((f"fig8a/{ds_name}", dt,
                     {"pool_bytes": gm.pool.memory_bytes(),
                      "disjoint_est_bytes": disjoint,
                      "snapshots_held": gm.pool.num_active() - 1}))
    return rows


def fig8b_partitioned(quick=False):
    """fig 8b: partitioned retrieval — per-partition critical-path bytes
    (the parallel-speedup driver; wall-clock parallelism needs >1 core)."""
    n = 4_000 if quick else 12_000
    uni, ev = _dataset2(n)
    times = _qtimes(ev, 10)
    rows = []
    for P in (1, 2, 4, 8):
        gm = GraphManager(uni, ev, L=max(len(ev) // 40, 64), k=4,
                          num_partitions=P)
        us = _time_queries(lambda t: gm.dg.get_snapshot(t, NO_ATTRS,
                                                        pool=gm.pool), times)
        # critical path = max per-partition bytes fetched
        gm.store.stats.reset()
        for t in times:
            gm.dg.get_snapshot(t, NO_ATTRS, pool=gm.pool)
        total = gm.store.stats.bytes_read
        rows.append((f"fig8b/P{P}", us,
                     {"bytes_total": total,
                      "bytes_critical_path": total // P}))
    return rows


def fig8c_multipoint(quick=False):
    """fig 8c: multipoint Steiner retrieval vs repeated singlepoint."""
    n = 4_000 if quick else 12_000
    uni, ev = _dataset1(n)
    rows = []
    for nq in (2, 4, 8, 16):
        gm = GraphManager(uni, ev, L=max(len(ev) // 40, 64), k=4)
        times = _qtimes(ev, nq)
        t0 = time.perf_counter()
        gm.dg.get_snapshots(times, NO_ATTRS, pool=gm.pool)
        multi = (time.perf_counter() - t0) * 1e6
        t0 = time.perf_counter()
        for t in times:
            gm.dg.get_snapshot(t, NO_ATTRS, pool=gm.pool)
        single = (time.perf_counter() - t0) * 1e6
        pm = gm.dg.plan_multipoint(times, NO_ATTRS)
        ps = sum(gm.dg.plan_singlepoint(t, NO_ATTRS).total_weight
                 for t in times)
        rows.append((f"fig8c/n{nq}", multi / nq,
                     {"singlepoint_us": single / nq,
                      "plan_bytes_multi": int(pm.total_weight),
                      "plan_bytes_single_sum": int(ps)}))
    return rows


def fig8d_columnar(quick=False):
    """fig 8d: structure-only vs +attributes retrieval (columnar win)."""
    n = 4_000 if quick else 12_000
    uni, ev = _dataset2(n, n_attrs=3)  # needs attribute traffic
    gm = GraphManager(uni, ev, L=max(len(ev) // 40, 64), k=4)
    times = _qtimes(ev, 15)
    opts_all = parse_attr_options("+node:all+edge:all", uni)
    rows = []
    for name, opts in (("structure", NO_ATTRS), ("all_attrs", opts_all)):
        gm.store.stats.reset()
        us = _time_queries(lambda t: gm.dg.get_snapshot(t, opts,
                                                        pool=gm.pool), times)
        rows.append((f"fig8d/{name}", us,
                     {"bytes_read": gm.store.stats.bytes_read}))
    return rows


def fig9_construction_params(quick=False):
    """fig 9: arity and leaf-eventlist-size sweeps."""
    n = 4_000 if quick else 12_000
    uni, ev = _dataset1(n)
    times = _qtimes(ev, 10)
    rows = []
    for k in (2, 4, 8) if quick else (2, 3, 4, 8, 16):
        gm = GraphManager(uni, ev, L=max(len(ev) // 40, 64), k=k)
        us = _time_queries(lambda t: gm.dg.get_snapshot(t, NO_ATTRS,
                                                        pool=gm.pool), times)
        rows.append((f"fig9/arity{k}", us,
                     {"storage_bytes": gm.store.total_bytes()}))
    for frac in (80, 40, 20, 10):
        L = max(len(ev) // frac, 32)
        gm = GraphManager(uni, ev, L=L, k=4)
        us = _time_queries(lambda t: gm.dg.get_snapshot(t, NO_ATTRS,
                                                        pool=gm.pool), times)
        rows.append((f"fig9/L{L}", us,
                     {"storage_bytes": gm.store.total_bytes()}))
    return rows


def fig10_materialization(quick=False):
    """fig 10: none / root / children / grandchildren materialized."""
    n = 4_000 if quick else 12_000
    uni, ev = _dataset2(n)
    times = _qtimes(ev, 15)
    rows = []
    for depth, name in ((0, "none"), (1, "root"), (2, "children"),
                        (3, "grandchildren")):
        gm = GraphManager(uni, ev, L=max(len(ev) // 40, 64), k=2,
                          diff_fn="intersection")
        if depth:
            gm.materialize_roots(depth=depth)
        us = _time_queries(lambda t: gm.dg.get_snapshot(t, NO_ATTRS,
                                                        pool=gm.pool), times)
        plan_bytes = int(np.mean([gm.dg.plan_singlepoint(t, NO_ATTRS).total_weight
                                  for t in times]))
        rows.append((f"fig10/{name}", us,
                     {"pool_bytes": gm.pool.memory_bytes(),
                      "materialized": gm.pool.num_active() - 1,
                      "plan_bytes_avg": plan_bytes}))
    return rows


def fig11_diff_functions(quick=False):
    """fig 11: Intersection vs Balanced vs Mixed(r1,r2) — retrieval-time
    distribution over history (slope = recency bias)."""
    n = 4_000 if quick else 12_000
    uni, ev = _dataset1(n)
    times = _qtimes(ev, 12)
    rows = []
    configs = [("intersection", {}), ("balanced", {}),
               ("mixed_r.75_.25", dict(r1=.75, r2=.25)),
               ("mixed_r.9_.1", dict(r1=.9, r2=.1))]
    for name, params in configs:
        fn = "mixed" if name.startswith("mixed") else name
        gm = GraphManager(uni, ev, L=max(len(ev) // 40, 64), k=2,
                          diff_fn=fn, diff_params=params)
        per_t = []
        weights = []
        for t in times:
            t0 = time.perf_counter()
            gm.dg.get_snapshot(t, NO_ATTRS, pool=gm.pool)
            per_t.append((time.perf_counter() - t0) * 1e6)
            weights.append(gm.dg.plan_singlepoint(t, NO_ATTRS).total_weight)
        # latency distribution over history = plan BYTES (deterministic;
        # our in-memory KV hides it in wall time) — fig 11's y axis
        old_b = float(np.mean(weights[:4]))
        new_b = float(np.mean(weights[-4:]))
        rows.append((f"fig11/{name}", float(np.mean(per_t)),
                     {"old_bytes": round(old_b), "recent_bytes": round(new_b),
                      "recency_ratio": round(new_b / max(old_b, 1e-9), 3)}))
    return rows


def bitmap_penalty(quick=False):
    """§7: PageRank with vs without GraphPool bitmap filtering
    (paper: <7% penalty)."""
    import jax.numpy as jnp
    from repro.core import bitmaps as bmod
    from repro.graph.algorithms import pagerank
    n = 4_000 if quick else 12_000
    uni, ev = _dataset2(n)
    truth = replay(uni, ev, int(ev.time[-1]))
    ep = jnp.asarray(bmod.np_pack(truth.edge_mask))
    np_plane = jnp.asarray(bmod.np_pack(truth.node_mask))
    ones_e = jnp.asarray(bmod.np_pack(np.ones(uni.num_edges, bool)))
    ones_n = jnp.asarray(bmod.np_pack(np.ones(uni.num_nodes, bool)))
    es, ed = jnp.asarray(uni.edge_src), jnp.asarray(uni.edge_dst)

    def run(e, n_):
        return pagerank(es, ed, e, n_, num_nodes=uni.num_nodes,
                        iters=20).block_until_ready()

    run(ep, np_plane)  # compile
    t0 = time.perf_counter()
    for _ in range(5):
        run(ones_e, ones_n)
    base = time.perf_counter() - t0
    t0 = time.perf_counter()
    for _ in range(5):
        run(ep, np_plane)
    masked = time.perf_counter() - t0
    return [("bitmap_penalty/pagerank", masked / 5 * 1e6,
             {"unmasked_us": round(base / 5 * 1e6),
              "penalty_pct": round((masked / base - 1) * 100, 2)})]


def subgraph_pattern_index(quick=False):
    """§4.7 anecdote: label-path pattern index over history."""
    from repro.core.auxiliary import AuxHistoryIndex, LabelPathIndex
    uni, ev = churn_network(n_initial_edges=60, n_events=300 if quick else 800,
                            seed=7, p_attr_update=0, p_transient=0)
    gm = GraphManager(uni, ev, L=64, k=2)
    labels = [("A", "B", "C")[i % 3] for i in range(uni.num_nodes)]
    t0 = time.perf_counter()
    ai = AuxHistoryIndex(LabelPathIndex(labels, plen=3), gm.dg, ev)
    build = time.perf_counter() - t0
    t0 = time.perf_counter()
    matches = sum(ai.snapshot_at(int(t)).get("A|B|C", 0)
                  for t in _qtimes(ev, 5))
    q = time.perf_counter() - t0
    return [("aux/labelpath3", q / 5 * 1e6,
             {"build_s": round(build, 2), "total_matches": int(matches)})]
