"""BENCH_replica: replicated shard *processes* behind the RPC transport —
process-isolation overhead, replica-hedged tail latency under one
degraded replica, and SIGKILL recovery.

The workload is a stream of multipoint snapshot queries against a
history whose store is wrapped with a simulated remote per-get RTT (the
same :class:`LatencyKV` budget for every configuration).  Three
acceptance gates (checked into the report as ``gates``):

* ``proc_overhead_lt_2x`` — proc-transport single-query p50 < 2x the
  in-thread transport at equal KV budget (the shard-local hot caches
  plus batched one-round-trip fetches must pay for the RPC hop);
* ``replica_hedged_tail`` — with one replica degraded (``set_delay``
  fault injection inside the shardd process), hedged p99 < 0.6x
  unhedged p99: the hedge routes to a *distinct* replica, so it never
  queues behind the degraded one;
* ``kill_recovery`` — SIGKILL one replica mid-stream: every query still
  completes (zero failures) and every result is bit-identical to the
  replay oracle.

Run standalone::

    PYTHONPATH=src python -m benchmarks.replica_bench --quick
"""
from __future__ import annotations

import json
import time

import numpy as np

from repro.core import GraphManager, replay
from repro.data.generators import churn_network
from repro.runtime.shard import ShardedRetriever

from .shard_bench import LatencyKV, GET_LATENCY_US
from repro.storage.kv import MemKV

OUT_JSON = "BENCH_replica.json"
PARTITIONS = 16
POINTS = 4
WORKERS = 2
REPLICAS = 2
DEGRADE_MS = 40.0         # per-fetch stall injected into the slow replica


def _queries(tmax: int, n: int, seed: int = 0) -> list[list[int]]:
    rng = np.random.default_rng(seed)
    return [sorted({int(t) for t in rng.integers(0, tmax + 1, POINTS)})
            for _ in range(n)]


def _stream(sr, queries, on_query=None) -> dict:
    lats, out, failures = [], [], 0
    t0 = time.perf_counter()
    for i, q in enumerate(queries):
        if on_query is not None:
            on_query(i, sr)
        tq = time.perf_counter()
        try:
            out.append(sr.retrieve(q))
        except Exception:
            failures += 1
            out.append(None)
        lats.append(time.perf_counter() - tq)
    wall = time.perf_counter() - t0
    lats_us = np.sort(np.asarray(lats)) * 1e6
    return {"qps": len(queries) / wall, "wall_s": wall,
            "p50_us": float(np.percentile(lats_us, 50)),
            "p99_us": float(np.percentile(lats_us, 99)),
            "hedges": sr.hedges_total, "requeues": sr.requeues_total,
            "failovers": sr.failovers_total, "failures": failures,
            "results": out}


def _row(res: dict) -> dict:
    return {k: round(v, 2) if isinstance(v, float) else v
            for k, v in res.items() if k != "results"}


def _identical(uni, ev, queries, results) -> bool:
    for q, got in zip(queries, results):
        if got is None:
            return False
        for t in q:
            truth = replay(uni, ev, t)
            if not (np.array_equal(got[t].node_mask, truth.node_mask)
                    and np.array_equal(got[t].edge_mask, truth.edge_mask)):
                return False
    return True


def bench_replica(quick: bool = False):
    n = 2_000 if quick else 6_000
    n_queries = 16 if quick else 40
    uni, ev = churn_network(n_initial_edges=n // 12, n_events=n, seed=9)
    tmax = int(ev.time[-1])
    queries = _queries(tmax, n_queries, seed=5)

    store = LatencyKV(MemKV(), GET_LATENCY_US * 1e-6)
    gm = GraphManager(uni, ev, store=store, L=max(n // 40, 64), k=2,
                      cache_bytes=0, prefetch_workers=0,
                      num_partitions=PARTITIONS, partition_fn="mod_hash",
                      diff_fn="intersection")

    rows = []
    report: dict = {"n_events": n, "partitions": PARTITIONS,
                    "n_queries": n_queries, "points_per_query": POINTS,
                    "workers": WORKERS, "replicas": REPLICAS,
                    "kv_get_latency_us": GET_LATENCY_US}

    # ---- overhead: proc transport vs in-thread at equal KV budget -------
    with ShardedRetriever(gm, WORKERS, max_hedges=0) as sr:
        thread_res = _stream(sr, queries)
    report["thread"] = _row(thread_res)
    rows.append(("replica/thread", thread_res["p50_us"], report["thread"]))

    with ShardedRetriever(gm, WORKERS, transport="proc", replicas=REPLICAS,
                          max_hedges=0) as sr:
        proc_res = _stream(sr, queries)
    report["proc"] = _row(proc_res)
    rows.append(("replica/proc", proc_res["p50_us"], report["proc"]))
    overhead = proc_res["p50_us"] / max(thread_res["p50_us"], 1e-9)
    report["proc_p50_over_thread_p50"] = round(overhead, 3)

    identical = (_identical(uni, ev, queries, thread_res["results"])
                 and _identical(uni, ev, queries, proc_res["results"]))

    # ---- tail: one degraded replica, hedged vs unhedged -----------------
    # Degrade the busiest server with an in-process per-fetch stall; a
    # hedged duplicate must route to a *different* replica of the same
    # partitions (ReplicaManager.route), so it never waits behind it.
    tail = {}
    for mode, hedges in (("unhedged", 0), ("hedged", 1)):
        with ShardedRetriever(gm, WORKERS, transport="proc",
                              replicas=REPLICAS, max_hedges=hedges,
                              hedge_frac=1.0, hedge_delay_s=3e-3) as sr:
            asg = sr.assignment(PARTITIONS)
            slow = max(asg, key=lambda w: len(asg[w]))
            sr.transport.inject_delay(slow, ms=DEGRADE_MS, count=-1)
            res = _stream(sr, queries)
            sr.transport.inject_delay(slow, ms=0.0, count=0)
        tail[mode] = res
        report[f"degraded_{mode}"] = _row(res)
        rows.append((f"replica/degraded_{mode}", res["p99_us"],
                     report[f"degraded_{mode}"]))
    p99_ratio = tail["hedged"]["p99_us"] / max(tail["unhedged"]["p99_us"],
                                               1e-9)
    report["hedged_p99_over_unhedged_p99"] = round(p99_ratio, 3)

    # ---- chaos: SIGKILL one replica mid-stream --------------------------
    kill_at = max(2, n_queries // 3)
    killed = []

    def killer(i: int, sr) -> None:
        if i == kill_at and not killed:
            victim = next(iter(sr.assignment(PARTITIONS)))
            killed.append(sr.transport.kill(victim))

    with ShardedRetriever(gm, WORKERS, transport="proc", replicas=REPLICAS,
                          task_retries=2, io_retries=2,
                          hedge_delay_s=5e-3) as sr:
        kill_res = _stream(sr, queries, on_query=killer)
    kill_ok = (kill_res["failures"] == 0 and bool(killed)
               and _identical(uni, ev, queries, kill_res["results"]))
    report["kill_recovery"] = {**_row(kill_res),
                               "killed_pid": killed[0] if killed else None,
                               "kill_at_query": kill_at}
    rows.append(("replica/kill_recovery", kill_res["p99_us"],
                 report["kill_recovery"]))

    report["gates"] = {
        "proc_overhead_lt_2x": bool(overhead < 2.0),
        "bit_identical": bool(identical),
        "replica_hedged_tail": bool(p99_ratio < 0.6),
        "kill_recovery": bool(kill_ok),
    }
    gm.close()

    with open(OUT_JSON, "w") as f:
        json.dump(report, f, indent=2)
    rows.append(("replica/report", 0.0,
                 {"json": OUT_JSON, **report["gates"]}))
    return rows


def main() -> None:
    import argparse
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true")
    args = ap.parse_args()
    print("name,us_per_call,derived")
    for name, us, derived in bench_replica(quick=args.quick):
        print(f"{name},{us:.1f},\"{json.dumps(derived)}\"", flush=True)


if __name__ == "__main__":
    main()
