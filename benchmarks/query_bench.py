"""BENCH_query: declarative query-document throughput vs direct
``get_snapshots`` calls.

The question the wire protocol must answer: what does the document layer
(JSON parse → validate → compile → stats envelope → JSON serialize) cost
on top of the retrieval it wraps?  Workload: batches of
``DOC_BATCH`` single-snapshot documents at random timepoints, served
three ways over identical data and an identical (cold-cache) manager:

* ``direct``    — one ``get_snapshots(batch)`` call per batch (the
  pre-API engine surface);
* ``documents`` — the same batches as NDJSON document strings through
  ``QueryService.run_batch`` (parse + compile + merged Steiner plan +
  envelope serialization), i.e. exactly what ``serve.py --mode query``
  does per chunk;
* ``parse+compile`` — the document-layer work alone (JSON parse +
  validate + compile, plus envelope serialization), measured directly.

The acceptance budget: at batch >= 8, the document layer costs < 5% of
the direct retrieval it wraps.  The gate is computed from the directly
measured layer time (``overhead_frac = layer_us / direct_us``) — the
end-to-end difference of the two loops is also reported, but wall-clock
differencing of two near-equal totals is dominated by machine noise.
Emits rows in the run.py contract and writes ``BENCH_query.json``.
Run standalone::

    PYTHONPATH=src python -m benchmarks.query_bench --quick
"""
from __future__ import annotations

import json
import time

import numpy as np

from repro.api.document import GraphQuery
from repro.api.service import QueryService
from repro.core import GraphManager
from repro.data.generators import churn_network

OUT_JSON = "BENCH_query.json"
DOC_BATCH = 8              # the acceptance point (budget: < 5% overhead)
OVERHEAD_BUDGET = 0.05


def _doc_lines(tmax: int, n_batches: int, seed: int = 0) -> list[list[str]]:
    rng = np.random.default_rng(seed)
    return [[json.dumps({"kind": "snapshot", "t": int(t)})
             for t in rng.integers(0, tmax + 1, DOC_BATCH)]
            for _ in range(n_batches)]


def bench_query(quick: bool = False):
    n = 4_000 if quick else 12_000
    n_batches = 20 if quick else 60
    uni, ev = churn_network(n_initial_edges=n // 12, n_events=n, seed=11)
    tmax = int(ev.time[-1])
    batches = _doc_lines(tmax, n_batches, seed=5)

    def fresh_gm() -> GraphManager:
        # cache disabled: every batch pays its real plan, so the measured
        # delta is the document layer, not cache-hit luck
        return GraphManager(uni, ev, L=max(n // 40, 64), k=2,
                            diff_fn="intersection", cache_bytes=0)

    def run_direct() -> float:
        with fresh_gm() as gm:
            t0 = time.perf_counter()
            for lines in batches:
                times = [json.loads(s)["t"] for s in lines]
                gm.get_snapshots(times)
            return time.perf_counter() - t0

    def run_documents() -> float:
        # parse -> compile -> merged plan -> envelope, per chunk — exactly
        # what serve.py --mode query does
        with fresh_gm() as gm:
            svc = gm.query
            t0 = time.perf_counter()
            for lines in batches:
                docs = [GraphQuery.from_json(s) for s in lines]
                for res in svc.run_batch(docs):
                    res.to_json()
            return time.perf_counter() - t0

    # interleaved reps, min-of-reps per engine: single-rep wall time at
    # this scale swings +-15% (allocator/GC), an order of magnitude above
    # the overhead being measured; the per-engine minimum is the standard
    # noise-floor estimator and the first rep doubles as process warm-up
    # (executor import, prefetch threads)
    docs_times: list[float] = []
    direct_times: list[float] = []
    for _ in range(3):
        docs_times.append(run_documents())
        direct_times.append(run_direct())
    docs_s, direct_s = min(docs_times), min(direct_times)

    # the document layer, measured directly: parse+compile, and envelope
    # serialization over real results
    with fresh_gm() as gm:
        svc = QueryService(gm)
        reps = 5
        t0 = time.perf_counter()
        for _ in range(reps):
            for lines in batches:
                for s in lines:
                    svc.compiler.compile(GraphQuery.from_json(s))
        compile_s = (time.perf_counter() - t0) / reps
        results = [r for lines in batches
                   for r in svc.run_batch([GraphQuery.from_json(s)
                                           for s in lines])]
        t0 = time.perf_counter()
        for _ in range(reps):
            for r in results:
                r.to_json()
        envelope_s = (time.perf_counter() - t0) / reps

    q = n_batches * DOC_BATCH
    layer_s = compile_s + envelope_s
    overhead = layer_s / direct_s
    report = {
        "n_events": n, "doc_batch": DOC_BATCH, "n_batches": n_batches,
        "direct_us_per_doc": direct_s / q * 1e6,
        "documents_us_per_doc": docs_s / q * 1e6,
        "parse_compile_us_per_doc": compile_s / q * 1e6,
        "envelope_us_per_doc": envelope_s / q * 1e6,
        "docs_per_s": q / docs_s,
        "overhead_frac": round(overhead, 4),
        "end_to_end_overhead_frac": round((docs_s - direct_s) / direct_s, 4),
        "overhead_budget": OVERHEAD_BUDGET,
        "within_budget": bool(overhead < OVERHEAD_BUDGET),
    }
    with open(OUT_JSON, "w") as f:
        json.dump(report, f, indent=2)
    return [
        ("query/direct", report["direct_us_per_doc"],
         {"docs_per_s": q / direct_s}),
        ("query/documents", report["documents_us_per_doc"],
         {"docs_per_s": report["docs_per_s"],
          "overhead_frac": report["overhead_frac"],
          "within_budget": report["within_budget"]}),
        ("query/parse_compile", report["parse_compile_us_per_doc"], {}),
        ("query/report", 0.0, {"json": OUT_JSON}),
    ]


def main() -> None:
    import argparse
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true")
    args = ap.parse_args()
    print("name,us_per_call,derived")
    for name, us, derived in bench_query(quick=args.quick):
        print(f"{name},{us:.1f},\"{json.dumps(derived)}\"", flush=True)


if __name__ == "__main__":
    main()
