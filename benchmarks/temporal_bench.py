"""BENCH_temporal: incremental interval analytics vs per-snapshot recompute.

The workload is evolutionary queries — PageRank / connected components /
raw snapshot masks tracked across dense 32-point intervals (the
"evolution of X over the last period" dashboards).  Two engines, same
GraphManager, same KV store behind the same simulated remote get latency
(equal KV budget):

* ``recompute``   — every timepoint planned, retrieved and solved cold
  (``evolve(..., incremental=False)``: the per-snapshot analytics loop);
* ``incremental`` — one planned retrieval per interval, inter-snapshot
  event-slice advancement, warm-started solvers
  (``core/temporal.py``).

Emits rows in the run.py contract and writes ``BENCH_temporal.json``
(acceptance: ``speedup_pagerank``/``speedup_components`` >= 3 on the
32-point intervals).  Run standalone::

    PYTHONPATH=src python -m benchmarks.temporal_bench --quick
"""
from __future__ import annotations

import json
import time

import numpy as np

from repro.core import GraphManager
from repro.data.generators import churn_network, dense_intervals

from .retrieval_bench import GET_LATENCY_US, LatencyKV
from repro.storage.kv import MemKV

OUT_JSON = "BENCH_temporal.json"
POINTS = 32               # timepoints per interval (the acceptance point)
WINDOW_FRAC = 0.04        # interval span as a fraction of the history
                          # (dense "daily snapshots over a period"
                          # dashboards: consecutive points differ by a
                          # small event slice, the workload the warm
                          # start exists for)


def bench_temporal(quick: bool = False):
    n = 4_000 if quick else 12_000
    n_intervals = 2 if quick else 5
    uni, ev = churn_network(n_initial_edges=n // 12, n_events=n, seed=11)
    tmax = int(ev.time[-1])
    intervals = dense_intervals(tmax, n_intervals, POINTS,
                                window_frac=WINDOW_FRAC, seed=2)

    store = LatencyKV(MemKV(), GET_LATENCY_US * 1e-6)
    gm = GraphManager(uni, ev, store=store, L=max(n // 40, 64), k=2,
                      diff_fn="intersection", cache_bytes=0)

    # tol=1e-5 is dashboard-grade: rank orderings are stable well above
    # it, and it is applied identically to both engines
    ops = [("pagerank", {"tol": 1e-5}), ("components", {}), ("masks", {})]
    report: dict = {"n_events": n, "points_per_interval": POINTS,
                    "n_intervals": n_intervals,
                    "kv_get_latency_us": GET_LATENCY_US, "ops": {}}
    rows = []
    reps = 2 if quick else 3
    q = n_intervals * POINTS
    for op, kw in ops:
        per_engine = {}
        # interleaved repeats, best-of per engine: the engines differ by
        # seconds while ambient scheduler noise on shared hosts is of the
        # same order — min-of-reps compares the engines, not the host
        walls = {"recompute": [], "incremental": []}
        for _ in range(reps):
            for engine in walls:
                store.stats.reset()
                iters = 0
                t0 = time.perf_counter()
                for iv in intervals:
                    res = gm.evolve(iv, op,
                                    incremental=(engine == "incremental"),
                                    **kw)
                    if res.stats["solver_iters"]:
                        iters += sum(res.stats["solver_iters"])
                walls[engine].append(time.perf_counter() - t0)
                per_engine[engine] = {
                    "kv_gets": store.stats.gets,
                    "kv_bytes_read": store.stats.bytes_read,
                    "solver_iters": iters}
        for engine, info in per_engine.items():
            wall = min(walls[engine])
            info.update(us_per_point=wall / q * 1e6, wall_s=wall,
                        wall_reps_s=[round(w, 4) for w in walls[engine]])
            rows.append((f"temporal/{op}/{engine}", info["us_per_point"],
                         dict(info, points=POINTS)))
        speed = (per_engine["recompute"]["us_per_point"]
                 / per_engine["incremental"]["us_per_point"])
        report["ops"][op] = per_engine
        report[f"speedup_{op}"] = round(speed, 3)
        report[f"kv_gets_saved_frac_{op}"] = round(
            1.0 - per_engine["incremental"]["kv_gets"]
            / max(per_engine["recompute"]["kv_gets"], 1), 3)

    gm.close()
    with open(OUT_JSON, "w") as f:
        json.dump(report, f, indent=2)
    rows.append(("temporal/report", 0.0, {"json": OUT_JSON}))
    return rows


def main() -> None:
    import argparse
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true")
    args = ap.parse_args()
    print("name,us_per_call,derived")
    for name, us, derived in bench_temporal(quick=args.quick):
        print(f"{name},{us:.1f},\"{json.dumps(derived)}\"", flush=True)


if __name__ == "__main__":
    main()
