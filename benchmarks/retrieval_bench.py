"""BENCH_retrieval: batched multipoint retrieval vs sequential per-query
retrieval at equal KV budget, with and without async prefetch.

The workload is B concurrent snapshot queries at distinct timepoints (the
"query stream" the batched engine exists for).  Three engines:

* ``sequential``      — one singlepoint plan + execute per query (the
  pre-IR engine's behaviour: identical prefixes re-fetched, re-applied);
* ``batched``         — one merged Steiner-plan DAG (shared prefixes
  fetch and apply once), host backend;
* ``batched+prefetch``— same DAG with the async KV prefetcher overlapping
  store gets with bitmap/state application.

All engines run against the *same* store wrapped with a simulated remote
round-trip latency (a Kyoto/Cassandra-style deployment; MemKV alone is
nanoseconds and would hide the fetch economics the planner optimizes).
Emits rows in the run.py contract and writes ``BENCH_retrieval.json``.
Run standalone::

    PYTHONPATH=src python -m benchmarks.retrieval_bench --quick
"""
from __future__ import annotations

import json
import time

import numpy as np

from repro.core import GraphManager
from repro.core.query import NO_ATTRS
from repro.data.generators import churn_network
from repro.runtime.executor import Prefetcher
from repro.storage.kv import KVStore, MemKV

OUT_JSON = "BENCH_retrieval.json"
CONCURRENCY = 16          # timepoints per batch (the acceptance point)
GET_LATENCY_US = 120.0    # simulated per-get remote RTT


class LatencyKV(KVStore):
    """Wraps a backend with a fixed per-get latency — the KV budget is
    identical for every engine (same blobs, same per-get cost)."""

    def __init__(self, inner: KVStore, get_latency_s: float) -> None:
        super().__init__()
        self.inner = inner
        self.lat = float(get_latency_s)

    def get(self, key):
        time.sleep(self.lat)
        v = self.inner.get(key)
        self.stats.add_get(len(v))
        return v

    def put(self, key, value):
        self.inner.put(key, value)
        self.stats.add_put(len(value))

    def delete(self, key):
        self.inner.delete(key)

    def __contains__(self, key):
        return key in self.inner

    def keys(self):
        return self.inner.keys()


def _batches(tmax: int, n_batches: int, seed: int = 0) -> list[list[int]]:
    rng = np.random.default_rng(seed)
    return [[int(t) for t in rng.integers(0, tmax + 1, CONCURRENCY)]
            for _ in range(n_batches)]


def bench_retrieval(quick: bool = False):
    n = 4_000 if quick else 12_000
    n_batches = 4 if quick else 10
    uni, ev = churn_network(n_initial_edges=n // 12, n_events=n, seed=7)
    L = max(n // 40, 64)
    tmax = int(ev.time[-1])
    batches = _batches(tmax, n_batches, seed=3)

    store = LatencyKV(MemKV(), GET_LATENCY_US * 1e-6)
    gm = GraphManager(uni, ev, store=store, L=L, k=2,
                      diff_fn="intersection", cache_bytes=0)
    dg, pool = gm.dg, gm.pool

    def run(mode: str) -> dict:
        store.stats.reset()
        pf = Prefetcher(store, workers=8) if mode == "batched+prefetch" else None
        t0 = time.perf_counter()
        for batch in batches:
            if mode == "sequential":
                for t in batch:
                    dg.get_snapshot(t, NO_ATTRS, pool=pool)
            else:
                dg.get_snapshots(batch, NO_ATTRS, pool=pool, prefetch=pf)
        wall = time.perf_counter() - t0
        if pf is not None:
            pf.close()
        q = sum(len(b) for b in batches)
        return {"us_per_q": wall / q * 1e6, "wall_s": wall,
                "kv_gets": store.stats.gets,
                "kv_bytes_read": store.stats.bytes_read}

    rows = []
    report: dict = {"n_events": n, "concurrency": CONCURRENCY,
                    "n_batches": n_batches,
                    "kv_get_latency_us": GET_LATENCY_US, "engines": {}}
    for mode in ("sequential", "batched", "batched+prefetch"):
        res = run(mode)
        report["engines"][mode] = res
        rows.append((f"retrieval/{mode}", res["us_per_q"],
                     dict(res, concurrency=CONCURRENCY)))

    seq = report["engines"]["sequential"]
    bat = report["engines"]["batched"]
    pfx = report["engines"]["batched+prefetch"]
    report["speedup_batched_vs_sequential"] = round(
        seq["us_per_q"] / bat["us_per_q"], 3)
    report["speedup_prefetch_vs_sequential"] = round(
        seq["us_per_q"] / pfx["us_per_q"], 3)
    report["speedup_prefetch_vs_batched"] = round(
        bat["us_per_q"] / pfx["us_per_q"], 3)
    report["kv_gets_saved_frac"] = round(
        1.0 - bat["kv_gets"] / max(seq["kv_gets"], 1), 3)

    with open(OUT_JSON, "w") as f:
        json.dump(report, f, indent=2)
    rows.append(("retrieval/report", 0.0, {"json": OUT_JSON}))
    return rows


def main() -> None:
    import argparse
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true")
    args = ap.parse_args()
    print("name,us_per_call,derived")
    for name, us, derived in bench_retrieval(quick=args.quick):
        print(f"{name},{us:.1f},\"{json.dumps(derived)}\"", flush=True)


if __name__ == "__main__":
    main()
