"""BENCH_shard: sharded multi-worker retrieval vs single-shard at equal
total KV budget, plus hedged-vs-unhedged tail latency under one slow
shard.

The workload is a stream of multipoint snapshot queries against a
history stored in ``P`` mod_hash partitions on one shared store wrapped
with a simulated remote per-get round-trip (same blobs, same per-get
cost for every configuration — the *total* KV budget is identical; only
the worker count changes).  Three acceptance gates (checked into the
report as ``gates``):

* ``qps_4x_ge_2x``   — 4-worker aggregate QPS >= 2x single-shard;
* ``bit_identical``  — every sharded result equals the single-shard
  replay oracle bit-for-bit;
* ``hedged_tail``    — with one shard stochastically slow, hedged p99
  < 0.6x unhedged p99 (first completion wins, the re-issued attempt
  re-samples the slowness).

Run standalone::

    PYTHONPATH=src python -m benchmarks.shard_bench --quick
"""
from __future__ import annotations

import json
import random
import time

import numpy as np

from repro.core import GraphManager, replay
from repro.core.query import NO_ATTRS
from repro.data.generators import churn_network
from repro.runtime.shard import ShardedRetriever
from repro.storage.kv import KVStore, MemKV

OUT_JSON = "BENCH_shard.json"
PARTITIONS = 16           # storage partitions (>> workers: balanced rings)
POINTS = 4                # timepoints per query
GET_LATENCY_US = 150.0    # simulated per-get remote RTT
SLOW_SCALE_MS = 100.0     # mean of the slow shard's per-attempt stall


class LatencyKV(KVStore):
    """Fixed per-get remote RTT — every configuration shares one
    instance: equal blobs, equal per-get cost, equal total KV budget."""

    def __init__(self, inner: KVStore, get_latency_s: float) -> None:
        super().__init__()
        self.inner = inner
        self.lat = float(get_latency_s)

    def get(self, key):
        time.sleep(self.lat)
        v = self.inner.get(key)
        self.stats.add_get(len(v))
        return v

    def put(self, key, value):
        self.inner.put(key, value)
        self.stats.add_put(len(value))

    def delete(self, key):
        self.inner.delete(key)

    def __contains__(self, key):
        return key in self.inner

    def keys(self):
        return self.inner.keys()


def _queries(tmax: int, n: int, seed: int = 0) -> list[list[int]]:
    rng = np.random.default_rng(seed)
    return [sorted({int(t) for t in rng.integers(0, tmax + 1, POINTS)})
            for _ in range(n)]


def _run(gm, workers: int, queries, reset=None, **kw) -> dict:
    lats = []
    with ShardedRetriever(gm, workers, **kw) as sr:
        t0 = time.perf_counter()
        out = []
        for q in queries:
            if reset is not None:
                reset()
            tq = time.perf_counter()
            out.append(sr.retrieve(q))
            lats.append(time.perf_counter() - tq)
        wall = time.perf_counter() - t0
        hedges, requeues = sr.hedges_total, sr.requeues_total
    lats_us = np.sort(np.asarray(lats)) * 1e6
    return {"qps": len(queries) / wall, "wall_s": wall,
            "p50_us": float(np.percentile(lats_us, 50)),
            "p99_us": float(np.percentile(lats_us, 99)),
            "hedges": hedges, "requeues": requeues,
            "results": out}


def bench_shard(quick: bool = False):
    n = 3_000 if quick else 8_000
    n_queries = 24 if quick else 60
    uni, ev = churn_network(n_initial_edges=n // 12, n_events=n, seed=7)
    tmax = int(ev.time[-1])
    queries = _queries(tmax, n_queries, seed=3)

    store = LatencyKV(MemKV(), GET_LATENCY_US * 1e-6)
    gm = GraphManager(uni, ev, store=store, L=max(n // 40, 64), k=2,
                      cache_bytes=0, prefetch_workers=0,
                      num_partitions=PARTITIONS, partition_fn="mod_hash",
                      diff_fn="intersection")

    rows = []
    report: dict = {"n_events": n, "partitions": PARTITIONS,
                    "n_queries": n_queries, "points_per_query": POINTS,
                    "kv_get_latency_us": GET_LATENCY_US, "workers": {}}

    # ---- throughput sweep: same store, same budget, more workers --------
    runs = {}
    for w in (1, 2, 4):
        res = _run(gm, w, queries, max_hedges=0)
        runs[w] = res
        row = {k: round(v, 2) if isinstance(v, float) else v
               for k, v in res.items() if k != "results"}
        report["workers"][str(w)] = row
        rows.append((f"shard/workers{w}", res["p50_us"], row))

    # ---- gate: bit-identical to the single-shard replay oracle ----------
    identical = True
    for q, single, multi in zip(queries, runs[1]["results"],
                                runs[4]["results"]):
        for t in q:
            truth = replay(uni, ev, t)
            for got in (single[t], multi[t]):
                if not (np.array_equal(got.node_mask, truth.node_mask)
                        and np.array_equal(got.edge_mask, truth.edge_mask)):
                    identical = False
    speedup = runs[4]["qps"] / runs[1]["qps"]
    report["qps_speedup_4w_vs_1w"] = round(speedup, 3)

    # ---- tail latency under one slow shard: hedged vs unhedged ----------
    # One shard is degraded: the *first* attempt it serves per query
    # stalls (floor + exponential tail — a slow replica / GC-pausing
    # process); a re-issued attempt takes a healthy path.  The largest
    # shard is the straggler — its task is the first assigned (largest
    # deficit), i.e. the oldest outstanding, so the hedging policy
    # duplicates exactly it and first completion wins.
    with ShardedRetriever(gm, 4) as probe:
        asg = probe.assignment(PARTITIONS)
    slow_worker = max(asg, key=lambda w: len(asg[w]))

    class DegradedShard:
        def __init__(self, seed: int) -> None:
            self.rng = random.Random(seed)
            self.calls = 0

        def reset(self) -> None:
            self.calls = 0

        def __call__(self, worker, parts) -> None:
            if worker != slow_worker:
                return
            self.calls += 1
            if self.calls == 1:
                time.sleep((0.5 + self.rng.expovariate(1.0))
                           * SLOW_SCALE_MS * 1e-3)

    tail = {}
    for mode, hedges in (("unhedged", 0), ("hedged", 1)):
        stall = DegradedShard(seed=11)
        res = _run(gm, 4, queries, reset=stall.reset, max_hedges=hedges,
                   hedge_frac=1.0, hedge_delay_s=2e-3,
                   shard_hook=stall)
        tail[mode] = res
        row = {k: round(v, 2) if isinstance(v, float) else v
               for k, v in res.items() if k != "results"}
        report[f"slow_shard_{mode}"] = row
        rows.append((f"shard/slow_{mode}", res["p99_us"], row))
    p99_ratio = tail["hedged"]["p99_us"] / tail["unhedged"]["p99_us"]
    report["hedged_p99_over_unhedged_p99"] = round(p99_ratio, 3)

    report["gates"] = {
        "qps_4x_ge_2x": bool(speedup >= 2.0),
        "bit_identical": bool(identical),
        "hedged_tail": bool(p99_ratio < 0.6),
    }
    gm.close()

    with open(OUT_JSON, "w") as f:
        json.dump(report, f, indent=2)
    rows.append(("shard/report", 0.0,
                 {"json": OUT_JSON, **report["gates"]}))
    return rows


def main() -> None:
    import argparse
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true")
    args = ap.parse_args()
    print("name,us_per_call,derived")
    for name, us, derived in bench_shard(quick=args.quick):
        print(f"{name},{us:.1f},\"{json.dumps(derived)}\"", flush=True)


if __name__ == "__main__":
    main()