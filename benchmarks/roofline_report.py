"""Roofline report generator: merges the dry-run sweep JSON with
registry-derived MODEL_FLOPS into the EXPERIMENTS.md tables.

Terms per (arch × shape × mesh), all per-chip:
  compute_s    = HLO_FLOPs / 197e12       (bf16 peak, v5e)
  memory_s     = HLO_bytes / 819e9        (HBM BW)
  collective_s = collective_bytes / 50e9  (ICI link BW)
MODEL_FLOPS = 6·N_active·D + 3·attn (train), 2·N_active·D + attn
(prefill/decode); roofline_fraction = ideal_compute_time / bound.
"""
from __future__ import annotations

import json
import os

PEAK_FLOPS = 197e12
HBM_BW = 819e9
ICI_BW = 50e9


def load_results(path: str) -> dict:
    with open(path) as f:
        return json.load(f)


def refresh_model_flops(results: dict) -> None:
    """Recompute MODEL_FLOPS from the (possibly newer) registry."""
    import numpy as np
    import jax
    from jax.sharding import Mesh
    from repro.configs.registry import get_cell
    mesh = Mesh(np.asarray(jax.devices()[:1]).reshape(1, 1),
                ("data", "model"))
    cache: dict = {}
    for key, rec in results.items():
        if rec.get("status") != "ok":
            continue
        ck = (rec["arch"], rec["shape"])
        if ck not in cache:
            cell = get_cell(rec["arch"], rec["shape"], mesh, False)
            cache[ck] = cell.flops_model
        rec["model_flops"] = cache[ck]
        h = rec.get("hlo")
        if not h:
            continue
        chips = rec["chips"]
        r = rec["roofline"]
        bound = max(r["compute_s"], r["memory_s"], r["collective_s"])
        ideal = rec["model_flops"] / (chips * PEAK_FLOPS)
        r["roofline_fraction"] = ideal / bound if bound else 0.0
        total = h["flops"] * chips
        r["useful_flops_ratio"] = rec["model_flops"] / total if total else 0.0


def table(results: dict, multi_pod: bool | None = False) -> str:
    hdr = ("| cell | chips | mem/dev GiB | fits | compute_s | memory_s | "
           "collective_s | bottleneck | useful | roofline |\n"
           "|---|---|---|---|---|---|---|---|---|---|\n")
    rows = []
    for key, r in sorted(results.items()):
        if r.get("status") == "skipped":
            if (multi_pod is None) or (r["multi_pod"] == multi_pod):
                reason = r.get("skip_reason", "")[:48]
                rows.append(f"| {r['arch']}×{r['shape']} | — | — | — | — | — "
                            f"| — | skipped: {reason} | — | — |")
            continue
        if r.get("status") != "ok":
            continue
        if multi_pod is not None and r["multi_pod"] != multi_pod:
            continue
        m = r.get("memory", {}).get("live_bytes_per_device", 0) / 2 ** 30
        rl = r.get("roofline", {})
        rows.append(
            f"| {r['arch']}×{r['shape']} | {r['chips']} | {m:.2f} | "
            f"{'✓' if r.get('fits_16gb') else '✗'} | "
            f"{rl.get('compute_s', 0):.2e} | {rl.get('memory_s', 0):.2e} | "
            f"{rl.get('collective_s', 0):.2e} | "
            f"{rl.get('bottleneck', '-').replace('_s', '')} | "
            f"{rl.get('useful_flops_ratio', 0):.3f} | "
            f"{rl.get('roofline_fraction', 0):.4f} |")
    return hdr + "\n".join(rows) + "\n"


def summary(results: dict) -> dict:
    ok = [r for r in results.values() if r.get("status") == "ok"]
    sk = [r for r in results.values() if r.get("status") == "skipped"]
    er = [r for r in results.values() if r.get("status") == "error"]
    fits = [r for r in ok if r.get("fits_16gb")]
    return {"ok": len(ok), "skipped": len(sk), "errors": len(er),
            "fits_16gb": len(fits),
            "over_budget": [k for k, r in results.items()
                            if r.get("status") == "ok"
                            and not r.get("fits_16gb")]}


def run(path: str = "dryrun_results.json", quick: bool = False):
    if not os.path.exists(path):
        return [("roofline/report", 0.0, {"error": f"{path} missing — run "
                 "PYTHONPATH=src python -m repro.launch.dryrun first"})]
    results = load_results(path)
    refresh_model_flops(results)
    with open(path, "w") as f:
        json.dump(results, f, indent=1)
    s = summary(results)
    rows = [("roofline/summary", 0.0, s)]
    for key, r in results.items():
        if r.get("status") != "ok":
            continue
        rl = r.get("roofline", {})
        rows.append((f"roofline/{key}", 0.0,
                     {"bottleneck": rl.get("bottleneck"),
                      "fraction": round(rl.get("roofline_fraction", 0), 4),
                      "mem_gib": round(r.get("memory", {}).get(
                          "live_bytes_per_device", 0) / 2 ** 30, 2)}))
    return rows
