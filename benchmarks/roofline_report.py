"""Roofline report generator: merges the dry-run sweep JSON with
registry-derived MODEL_FLOPS into the EXPERIMENTS.md tables, plus the
*measured* device-path benchmark (``bench_device`` → BENCH_device.json).

Terms per (arch × shape × mesh), all per-chip:
  compute_s    = HLO_FLOPs / 197e12       (bf16 peak, v5e)
  memory_s     = HLO_bytes / 819e9        (HBM BW)
  collective_s = collective_bytes / 50e9  (ICI link BW)
MODEL_FLOPS = 6·N_active·D + 3·attn (train), 2·N_active·D + attn
(prefill/decode); roofline_fraction = ideal_compute_time / bound.

``bench_device`` measures the fused delta-apply + analytics retrieval
against the pre-fusion pipeline (XLA-scan chain, host round-trip, separate
unpack/popcount/degree-feed/weighted passes) producing the *same outputs*,
reports achieved logical bytes/s for both, and asserts the fused path's
analytics stay bit-identical to the ``ref.py`` oracle.  Run standalone::

    PYTHONPATH=src python -m benchmarks.roofline_report --device --quick
"""
from __future__ import annotations

import json
import os
import time

PEAK_FLOPS = 197e12
HBM_BW = 819e9
ICI_BW = 50e9

DEVICE_JSON = "BENCH_device.json"


def load_results(path: str) -> dict:
    with open(path) as f:
        return json.load(f)


def refresh_model_flops(results: dict) -> None:
    """Recompute MODEL_FLOPS from the (possibly newer) registry."""
    import numpy as np
    import jax
    from jax.sharding import Mesh
    from repro.configs.registry import get_cell
    mesh = Mesh(np.asarray(jax.devices()[:1]).reshape(1, 1),
                ("data", "model"))
    cache: dict = {}
    for key, rec in results.items():
        if rec.get("status") != "ok":
            continue
        ck = (rec["arch"], rec["shape"])
        if ck not in cache:
            cell = get_cell(rec["arch"], rec["shape"], mesh, False)
            cache[ck] = cell.flops_model
        rec["model_flops"] = cache[ck]
        h = rec.get("hlo")
        if not h:
            continue
        chips = rec["chips"]
        r = rec["roofline"]
        bound = max(r["compute_s"], r["memory_s"], r["collective_s"])
        ideal = rec["model_flops"] / (chips * PEAK_FLOPS)
        r["roofline_fraction"] = ideal / bound if bound else 0.0
        total = h["flops"] * chips
        r["useful_flops_ratio"] = rec["model_flops"] / total if total else 0.0


def table(results: dict, multi_pod: bool | None = False) -> str:
    hdr = ("| cell | chips | mem/dev GiB | fits | compute_s | memory_s | "
           "collective_s | bottleneck | useful | roofline |\n"
           "|---|---|---|---|---|---|---|---|---|---|\n")
    rows = []
    for key, r in sorted(results.items()):
        if r.get("status") == "skipped":
            if (multi_pod is None) or (r["multi_pod"] == multi_pod):
                reason = r.get("skip_reason", "")[:48]
                rows.append(f"| {r['arch']}×{r['shape']} | — | — | — | — | — "
                            f"| — | skipped: {reason} | — | — |")
            continue
        if r.get("status") != "ok":
            continue
        if multi_pod is not None and r["multi_pod"] != multi_pod:
            continue
        m = r.get("memory", {}).get("live_bytes_per_device", 0) / 2 ** 30
        rl = r.get("roofline", {})
        rows.append(
            f"| {r['arch']}×{r['shape']} | {r['chips']} | {m:.2f} | "
            f"{'✓' if r.get('fits_16gb') else '✗'} | "
            f"{rl.get('compute_s', 0):.2e} | {rl.get('memory_s', 0):.2e} | "
            f"{rl.get('collective_s', 0):.2e} | "
            f"{rl.get('bottleneck', '-').replace('_s', '')} | "
            f"{rl.get('useful_flops_ratio', 0):.3f} | "
            f"{rl.get('roofline_fraction', 0):.4f} |")
    return hdr + "\n".join(rows) + "\n"


def summary(results: dict) -> dict:
    ok = [r for r in results.values() if r.get("status") == "ok"]
    sk = [r for r in results.values() if r.get("status") == "skipped"]
    er = [r for r in results.values() if r.get("status") == "error"]
    fits = [r for r in ok if r.get("fits_16gb")]
    return {"ok": len(ok), "skipped": len(sk), "errors": len(er),
            "fits_16gb": len(fits),
            "over_budget": [k for k, r in results.items()
                            if r.get("status") == "ok"
                            and not r.get("fits_16gb")]}


# ---------------------------------------------------------------------------
# measured device path: fused retrieval+analytics vs the pre-fusion pipeline
# ---------------------------------------------------------------------------


def _bench_loop(fn, reps: int) -> float:
    fn()                       # warmup / compile
    best = float("inf")
    for _ in range(reps):
        t0 = time.perf_counter()
        fn()
        best = min(best, time.perf_counter() - t0)
    return best


def bench_device(quick: bool = False):
    """Fused kernel family vs the separate-pass baseline, same outputs.

    Both paths produce (landed mask, per-block popcounts, per-word weighted
    partials, unpacked live f32 feed).  The baseline is the pre-fusion
    pipeline: XLA-scan chain call, device→host round-trip, then host-side
    unpack + popcount + weighted reductions (exactly what the training
    example's ``snapshot_batch`` and the analytics ops used to do).  The
    fused path is one compiled call.  On this CPU container both run
    through XLA — the interpret-comparable measurement of the kernel
    fusion itself; on TPU the same entry points lower through Mosaic.
    Achieved bytes/s counts the logical chain traffic (K+2 planes of W
    words), the quantity the roofline's HBM term bounds.
    """
    import numpy as np
    import jax.numpy as jnp
    from repro.core import bitmaps as bmod
    from repro.kernels import delta_apply_fused
    from repro.kernels.delta_apply.ops import _fused_pad
    from repro.kernels.delta_apply import (delta_apply_chain,
                                           delta_apply_fused_ref)

    W = 1 << 14 if quick else 1 << 16     # words: 2^19 / 2^21 slots
    K = 8 if quick else 16
    reps = 5 if quick else 10
    U = W * 32
    rng = np.random.default_rng(0)
    base = jnp.asarray(rng.integers(0, 2 ** 32, W, dtype=np.uint32))
    adds = jnp.asarray(rng.integers(0, 2 ** 32, (K, W), dtype=np.uint32))
    dels = jnp.asarray(rng.integers(0, 2 ** 32, (K, W), dtype=np.uint32))
    weights = jnp.asarray(rng.random(U, dtype=np.float32))
    logical_bytes = (K + 2) * W * 4

    def fused():
        out = delta_apply_fused(base, adds, dels, weights, impl="xla")
        return (np.asarray(out.mask), np.asarray(out.pop),
                np.asarray(out.accw), np.asarray(out.live))

    G = W // 1024

    def baseline():
        m = np.asarray(delta_apply_chain(base, adds, dels, impl="xla"))
        live = bmod.np_unpack(m, U).astype(np.float32)
        pop = (np.unpackbits(m.view(np.uint8)).astype(np.int32)
               .reshape(G, -1).sum(axis=1))
        accw = (live * np.asarray(weights)).reshape(W, 32).sum(axis=1)
        return m, pop, accw, live

    t_fused = _bench_loop(fused, reps)
    t_base = _bench_loop(baseline, reps)

    # bit-identity vs the oracle (the acceptance gate for fused analytics)
    pb, pa, pd, pw, _ = _fused_pad(base, adds, dels, weights, 1024)
    rm, rp, ra, rl = delta_apply_fused_ref(pb, pa, pd, pw, block_w=1024)
    fo = delta_apply_fused(base, adds, dels, weights, impl="xla")
    parity = bool(
        np.array_equal(np.asarray(fo.mask), np.asarray(rm[:W]))
        and np.array_equal(np.asarray(fo.pop), np.asarray(rp))
        and np.array_equal(np.asarray(fo.accw), np.asarray(ra[:W]))
        and np.array_equal(np.asarray(fo.live), np.asarray(rl[:U])))

    fused_gbps = logical_bytes / t_fused / 1e9
    base_gbps = logical_bytes / t_base / 1e9
    report = {
        "W_words": W, "K": K, "slots": U,
        "logical_bytes_per_apply": logical_bytes,
        "fused_s": t_fused, "baseline_s": t_base,
        "fused_gbps": round(fused_gbps, 3),
        "baseline_gbps": round(base_gbps, 3),
        "speedup_fused_vs_baseline": round(t_base / t_fused, 3),
        "hbm_fraction_of_v5e": round(fused_gbps * 1e9 / HBM_BW, 5),
        "analytics_bit_identical_to_ref": parity,
    }
    with open(DEVICE_JSON, "w") as f:
        json.dump(report, f, indent=2)
    return [
        ("device/fused_apply", t_fused * 1e6,
         {"gbps": report["fused_gbps"], "parity": parity}),
        ("device/baseline_separate_passes", t_base * 1e6,
         {"gbps": report["baseline_gbps"]}),
        ("device/report", 0.0,
         {"json": DEVICE_JSON,
          "speedup": report["speedup_fused_vs_baseline"]}),
    ]


def run(path: str = "dryrun_results.json", quick: bool = False):
    if not os.path.exists(path):
        return [("roofline/report", 0.0, {"error": f"{path} missing — run "
                 "PYTHONPATH=src python -m repro.launch.dryrun first"})]
    results = load_results(path)
    refresh_model_flops(results)
    with open(path, "w") as f:
        json.dump(results, f, indent=1)
    s = summary(results)
    rows = [("roofline/summary", 0.0, s)]
    for key, r in results.items():
        if r.get("status") != "ok":
            continue
        rl = r.get("roofline", {})
        rows.append((f"roofline/{key}", 0.0,
                     {"bottleneck": rl.get("bottleneck"),
                      "fraction": round(rl.get("roofline_fraction", 0), 4),
                      "mem_gib": round(r.get("memory", {}).get(
                          "live_bytes_per_device", 0) / 2 ** 30, 2)}))
    return rows


def main() -> None:
    import argparse
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true")
    ap.add_argument("--device", action="store_true",
                    help="run the measured device-path benchmark only")
    ap.add_argument("--dryrun-json", default="dryrun_results.json")
    args = ap.parse_args()
    print("name,us_per_call,derived")
    rows = (bench_device(quick=args.quick) if args.device
            else run(args.dryrun_json, quick=args.quick))
    for name, us, derived in rows:
        print(f"{name},{us:.1f},\"{json.dumps(derived)}\"")


if __name__ == "__main__":
    main()
