"""BENCH_ingest: sustained ingest rate under group commit, query latency
under write pressure, and freshness lag.

Three measurements over identical synthetic churn histories on a
disk-backed ``LogFileKV`` (fsync is the phenomenon being measured):

* ``single`` — the naive durable write path: one event per commit group,
  so one WAL append **and one fsync per event**;
* ``grouped`` — the pipeline's group commit: the same events in
  ``GROUP``-event groups, one fsync per group.  The acceptance gate is
  ``grouped >= 10x single`` events/s;
* ``query under ingest`` — snapshot-query p99 on an idle manager vs the
  same queries while the threaded pipeline commits and rolls over
  continuously.  Gate: concurrent p99 < 2x idle p99 (epoch pinning means
  readers never block on the writer).

Freshness lag (event append → visible in a pinned query view) comes from
the pipeline's per-group enqueue→publish clock and is reported as
mean / p99 ms.  Emits rows in the run.py contract and writes
``BENCH_ingest.json``.  Run standalone::

    PYTHONPATH=src python -m benchmarks.ingest_bench --quick
"""
from __future__ import annotations

import json
import tempfile
import threading
import time

import numpy as np

from repro.core import GraphManager
from repro.core.ingest import IngestPipeline
from repro.data.generators import churn_network
from repro.storage.kv import LogFileKV, TieredKV

OUT_JSON = "BENCH_ingest.json"
GROUP = 256
SPEEDUP_GATE = 10.0
P99_DEGRADATION_GATE = 2.0


def _p99(xs: list[float]) -> float:
    return float(np.quantile(np.asarray(xs), 0.99)) if xs else float("nan")


L = 512                    # leaf size: rollovers amortize over L events


def _ingest_rate(uni, ev, n_build: int, chunk: int, group: int) -> dict:
    """Events/s streaming ev[n_build:] in ``chunk``-event appends with
    commit groups of ``group`` events (group=chunk → one fsync per
    append; chaining chunk=1 models per-event durability)."""
    tmp = tempfile.mkdtemp(prefix="bench-ingest-")
    gm = GraphManager(uni, ev[:n_build], L=L, k=2,
                      diff_fn="intersection", store=LogFileKV(tmp))
    pipe = IngestPipeline(gm, group_events=group)
    gm._ingest = pipe
    n = len(ev) - n_build
    t0 = time.perf_counter()
    for i in range(n_build, len(ev), chunk):
        pipe.append(ev[i:i + chunk])
    wall = time.perf_counter() - t0
    stats = pipe.stats()
    gm.close()
    return {"events_per_s": n / wall, "wall_s": wall,
            "groups": stats["groups_committed"],
            "rollovers": stats["rollovers"],
            "freshness_lag_mean_ms": stats["freshness_lag_mean_ms"],
            "freshness_lag_p99_ms": stats["freshness_lag_p99_ms"]}


def _query_p99(gm, times, n_queries: int, repeats: int = 3) -> float:
    """Median of ``repeats`` consecutive per-batch p99s.  A p99 over a few
    hundred samples is set by its 1-3 worst outliers, so a single
    scheduler or filesystem hiccup would otherwise decide the gate; the
    median keeps the measurement about the system, not the fluke."""
    from repro.api.document import Q
    svc = gm.query
    rng = np.random.default_rng(3)
    p99s = []
    for _ in range(repeats):
        lats = []
        for t in rng.choice(times, size=n_queries):
            # .fresh(): bypass the snapshot cache so every query pays a
            # real plan — cache-hit luck would mask writer interference
            doc = Q.at(int(t)).attrs("+node:all").fresh().build()
            t0 = time.perf_counter()
            svc.run(doc)
            lats.append(time.perf_counter() - t0)
        p99s.append(_p99(lats))
    return float(np.median(p99s))


def bench_ingest(quick: bool = False):
    n = 3_000 if quick else 10_000
    n_single = 150 if quick else 400      # per-event fsync is slow by design
    # per-batch sample count (x3 batches in _query_p99): enough that p99
    # is a real percentile, small enough that all three busy batches fit
    # inside the paced writer's active window
    n_queries = 250 if quick else 500
    uni, ev = churn_network(n_initial_edges=max(n // 12, 50),
                            n_events=n, seed=21)
    n_build = n // 5

    # -- single-event-fsync baseline over a truncated stream ---------------
    short = ev[:n_build + n_single]
    single = _ingest_rate(uni, short, n_build, chunk=1, group=1)
    # -- group commit over the full stream ---------------------------------
    grouped = _ingest_rate(uni, ev, n_build, chunk=GROUP, group=GROUP)
    speedup = grouped["events_per_s"] / single["events_per_s"]

    # -- query latency: idle vs concurrent ingest --------------------------
    # hot-tier reads: queries must not share the WAL's log file (an fsync
    # in flight can block a same-file read at the filesystem level)
    tmp = tempfile.mkdtemp(prefix="bench-ingest-q-")
    gm = GraphManager(uni, ev[:n // 2], L=L, k=2,
                      diff_fn="intersection",
                      store=TieredKV(LogFileKV(tmp), hot_bytes=64 << 20))
    tmax_idle = int(ev.time[n // 2 - 1])
    times = np.linspace(0, tmax_idle, 128).astype(int)
    idle_p99 = _query_p99(gm, times, n_queries)

    pipe = IngestPipeline(gm, group_events=64, threaded=True)
    gm._ingest = pipe
    stop = threading.Event()

    def writer() -> None:
        # paced at ~2k events/s — a sustained production write rate below
        # the box's fold-saturation point.  Tail latency is only defined
        # at an offered load the system can absorb; at saturation every
        # system's p99 is unbounded (classic latency-vs-throughput
        # separation — the throughput half is the group-commit gate above)
        i = n // 2
        while not stop.is_set():
            j = min(n, i + 32)
            if i < j:
                pipe.submit(ev[i:j])
                i = j
            time.sleep(0.016)

    th = threading.Thread(target=writer, daemon=True)
    th.start()
    busy_p99 = _query_p99(gm, times, n_queries)
    stop.set()
    th.join(timeout=30)
    pipe.drain(timeout=60)
    degradation = busy_p99 / idle_p99
    gm.close()

    report = {
        "n_events": n, "group": GROUP,
        "single_fsync_events_per_s": round(single["events_per_s"], 1),
        "grouped_events_per_s": round(grouped["events_per_s"], 1),
        "group_commit_speedup": round(speedup, 2),
        "speedup_gate": SPEEDUP_GATE,
        "speedup_ok": bool(speedup >= SPEEDUP_GATE),
        "freshness_lag_mean_ms": round(
            grouped["freshness_lag_mean_ms"] or 0.0, 3),
        "freshness_lag_p99_ms": round(
            grouped["freshness_lag_p99_ms"] or 0.0, 3),
        "idle_query_p99_ms": round(idle_p99 * 1e3, 3),
        "concurrent_query_p99_ms": round(busy_p99 * 1e3, 3),
        "p99_degradation": round(degradation, 2),
        "p99_gate": P99_DEGRADATION_GATE,
        "p99_ok": bool(degradation < P99_DEGRADATION_GATE),
        "rollovers": grouped["rollovers"],
    }
    with open(OUT_JSON, "w") as f:
        json.dump(report, f, indent=2)
    return [
        ("ingest/single_fsync", 1e6 / single["events_per_s"],
         {"events_per_s": report["single_fsync_events_per_s"]}),
        ("ingest/group_commit", 1e6 / grouped["events_per_s"],
         {"events_per_s": report["grouped_events_per_s"],
          "speedup": report["group_commit_speedup"],
          "speedup_ok": report["speedup_ok"],
          "freshness_lag_p99_ms": report["freshness_lag_p99_ms"]}),
        ("ingest/query_under_ingest", report["concurrent_query_p99_ms"],
         {"idle_p99_ms": report["idle_query_p99_ms"],
          "degradation": report["p99_degradation"],
          "p99_ok": report["p99_ok"]}),
        ("ingest/report", 0.0, {"json": OUT_JSON}),
    ]


def main() -> None:
    import argparse
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true")
    args = ap.parse_args()
    print("name,us_per_call,derived")
    for name, us, derived in bench_ingest(quick=args.quick):
        print(f"{name},{us:.1f},\"{json.dumps(derived)}\"", flush=True)


if __name__ == "__main__":
    main()
