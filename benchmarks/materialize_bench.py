"""BENCH_materialize: workload-aware materialization advisor + snapshot
cache vs cold retrieval and the fixed-depth §4.5 heuristic, at *equal*
GraphPool memory budget.

Emits rows in the run.py contract and writes ``BENCH_materialize.json``
with the headline speedups.  Run standalone::

    PYTHONPATH=src python -m benchmarks.materialize_bench --quick
"""
from __future__ import annotations

import json
import time

import numpy as np

from repro.core import GraphManager
from repro.core.query import NO_ATTRS
from repro.data.generators import churn_network

OUT_JSON = "BENCH_materialize.json"


def _skewed_times(tmax: int, n: int, seed: int = 0,
                  zipf: float = 1.3) -> list[int]:
    """Recency-skewed query times over 256 distinct points (hot recent
    head + long historical tail — the snapshot-dashboard shape)."""
    rng = np.random.default_rng(seed)
    distinct = np.sort(rng.integers(0, tmax + 1, 256))
    ranks = np.minimum(rng.zipf(zipf, n), distinct.size - 1)
    return [int(t) for t in distinct[distinct.size - 1 - ranks]]


def _measure(fn, times) -> tuple[float, float]:
    t0 = time.perf_counter()
    for t in times:
        fn(t)
    dt = time.perf_counter() - t0
    return dt / len(times) * 1e6, dt


def _plan_bytes(gm: GraphManager, times) -> float:
    sample = times[:: max(len(times) // 64, 1)]
    return float(np.mean([gm.dg.plan_singlepoint(t, NO_ATTRS).total_weight
                          for t in sample]))


def _fixed_depth_under_budget(uni, ev, L: int, budget: int) -> GraphManager:
    """The pre-advisor heuristic: deepest materialize_roots() whose pool
    stays under the budget."""
    best = GraphManager(uni, ev, L=L, k=2, diff_fn="intersection",
                        cache_bytes=0)
    for depth in (1, 2, 3, 4):
        gm = GraphManager(uni, ev, L=L, k=2, diff_fn="intersection",
                          cache_bytes=0)
        gm.materialize_roots(depth=depth)
        if gm.pool.memory_bytes() > budget:
            break
        best = gm
    return best


def bench_materialize(quick: bool = False):
    n = 6_000 if quick else 20_000
    budget = 16 << 20
    uni, ev = churn_network(n_initial_edges=n // 12, n_events=n, seed=42)
    L = max(n // 40, 64)
    tmax = int(ev.time[-1])
    rows = []
    report: dict = {"n_events": n, "budget_bytes": budget, "workloads": {}}

    for wname, times in (("skewed", _skewed_times(tmax, 1500, seed=1)),
                         ("uniform", [int(t) for t in
                                      np.random.default_rng(2).integers(
                                          0, tmax + 1, 600)])):
        res: dict = {}

        cold = GraphManager(uni, ev, L=L, k=2, diff_fn="intersection",
                            cache_bytes=0)
        us, _ = _measure(lambda t: cold.dg.get_snapshot(t, pool=cold.pool),
                         times)
        res["cold"] = {"us_per_q": us, "plan_bytes": _plan_bytes(cold, times),
                       "pool_bytes": cold.pool.memory_bytes()}
        rows.append((f"materialize/{wname}/cold", us,
                     dict(res["cold"], workload=wname)))

        fixed = _fixed_depth_under_budget(uni, ev, L, budget)
        us, _ = _measure(lambda t: fixed.dg.get_snapshot(t, pool=fixed.pool),
                         times)
        res["fixed_depth"] = {"us_per_q": us,
                              "plan_bytes": _plan_bytes(fixed, times),
                              "pool_bytes": fixed.pool.memory_bytes()}
        rows.append((f"materialize/{wname}/fixed-depth", us,
                     dict(res["fixed_depth"], workload=wname)))

        adv = GraphManager(uni, ev, L=L, k=2, diff_fn="intersection",
                           cache_bytes=0)
        adv.enable_advisor(budget_bytes=budget, replan_every=256)
        # let the advisor see the head of the workload, then replan once
        for t in times[:128]:
            adv.get_snapshot(t)
        adv.advisor.replan()
        us, _ = _measure(lambda t: adv.get_snapshot(t), times)
        res["advised"] = {"us_per_q": us, "plan_bytes": _plan_bytes(adv, times),
                          "pool_bytes": adv.pool.memory_bytes(),
                          "pins": len(adv.advisor.pinned)}
        rows.append((f"materialize/{wname}/advised", us,
                     dict(res["advised"], workload=wname)))

        full = GraphManager(uni, ev, L=L, k=2, diff_fn="intersection")
        full.enable_advisor(budget_bytes=budget, replan_every=256)
        us, _ = _measure(lambda t: full.get_snapshot(t), times)
        res["advised_cached"] = {
            "us_per_q": us, "pool_bytes": full.pool.memory_bytes(),
            "cache_hits": full.cache.hits,
            "cache_misses": full.cache.misses,
            "cache_bytes": full.cache.nbytes()}
        rows.append((f"materialize/{wname}/advised+cache", us,
                     dict(res["advised_cached"], workload=wname)))

        res["speedup_advised_vs_cold"] = round(
            res["cold"]["us_per_q"] / res["advised"]["us_per_q"], 3)
        res["speedup_cached_vs_cold"] = round(
            res["cold"]["us_per_q"] / res["advised_cached"]["us_per_q"], 3)
        res["speedup_advised_vs_fixed"] = round(
            res["fixed_depth"]["us_per_q"] / res["advised"]["us_per_q"], 3)
        report["workloads"][wname] = res

    with open(OUT_JSON, "w") as f:
        json.dump(report, f, indent=2)
    rows.append(("materialize/report", 0.0, {"json": OUT_JSON}))
    return rows


def main() -> None:
    import argparse
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true")
    args = ap.parse_args()
    print("name,us_per_call,derived")
    for name, us, derived in bench_materialize(quick=args.quick):
        print(f"{name},{us:.1f},\"{json.dumps(derived)}\"", flush=True)


if __name__ == "__main__":
    main()
