"""BENCH_server: the concurrent SLO-aware query server under load.

Closed- and open-loop Zipf snapshot traffic from ~1k short-lived client
sessions (waves of concurrent connections) against one
:class:`~repro.launch.server.QueryServer`, all over real sockets.  Four
acceptance gates (checked into the report as ``gates``):

* ``cobatch_qps``     — cross-client co-batching (batching window on)
  delivers >= 1.5x the aggregate closed-loop QPS of ``window=0`` at
  equal KV budget (same store, same per-get cost, same worker count);
* ``p99_bounded``     — open-loop at 2x measured capacity, admission
  control sheds enough load that the p99 of *admitted* requests stays
  < 3x the pre-saturation (0.5x capacity) p99 instead of melting down;
* ``deadline_no_kv``  — deadline-rejected requests consume zero KV gets;
* ``no_cross_wiring`` — a differential session oracle: every envelope
  answers exactly its session's request (correlation id, request order)
  and is bit-identical (CRCs) to a direct single-client execution.

``--smoke`` is the CI contract: boot the socket server, fire a
200-request mixed Zipf burst over concurrent sessions, require every
envelope valid and no leaked threads or fds, print ``SMOKE_OK``.

Run standalone::

    PYTHONPATH=src python -m benchmarks.server_bench --quick
"""
from __future__ import annotations

import json
import socket
import threading
import time

import numpy as np

from repro.api.document import Q
from repro.core import GraphManager
from repro.data.generators import churn_network
from repro.launch.server import QueryServer

from .shard_bench import LatencyKV, MemKV

OUT_JSON = "BENCH_server.json"
GET_LATENCY_US = 300.0    # simulated per-get remote RTT (equal everywhere)
ZIPF = 1.2
DISTINCT_TIMES = 64
WINDOW_MS = 6.0           # generous window: closed-loop waves merge fully
WORKERS = 4
ADMIT_MS = 25.0           # drain horizon for the saturation runs


def _build(n_events: int, seed: int = 7):
    uni, ev = churn_network(n_initial_edges=max(n_events // 12, 50),
                            n_events=n_events, seed=seed)
    store = LatencyKV(MemKV(), GET_LATENCY_US * 1e-6)
    # async KV prefetch stays ON: merged multipoint plans overlap their
    # fetches; single-point documents cannot — that asymmetry is the
    # multi-query optimization the co-batching gate measures
    gm = GraphManager(uni, ev, store=store, L=max(n_events // 40, 64),
                      k=2, diff_fn="intersection", cache_bytes=0)
    return gm


def _zipf_times(tmax: int, seed: int = 3) -> np.ndarray:
    rng = np.random.default_rng(seed)
    return np.sort(rng.integers(0, tmax + 1, DISTINCT_TIMES))


def _draw(times: np.ndarray, rng) -> int:
    rank = min(int(rng.zipf(ZIPF)), times.size)
    return int(times[times.size - rank])


def _oracle(gm, times: np.ndarray) -> dict:
    out = {}
    for t in np.unique(times):
        r = gm.query.run(Q.at(int(t)).build()).to_dict()["result"]
        out[int(t)] = (r["nodes"], r["edges"], r["node_crc"],
                       r["edge_crc"])
    return out


def _check(env: dict, rid: str, oracle: dict) -> str | None:
    if env.get("id") != rid:
        return f"cross-wired: sent {rid}, got {env.get('id')}"
    if not env.get("ok"):
        return f"{rid}: {env.get('error')}"
    t = int(rid.rsplit("t", 1)[1])
    r = env["result"]
    if (r["nodes"], r["edges"], r["node_crc"], r["edge_crc"]) != oracle[t]:
        return f"{rid}: payload differs from direct execution"
    return None


# --------------------------------------------------------------- closed loop


def _closed_loop(srv: QueryServer, times: np.ndarray, oracle: dict, *,
                 concurrency: int, sessions_per_worker: int,
                 reqs_per_session: int) -> dict:
    """Waves of short-lived sessions: ``concurrency`` live connections,
    each worker thread running ``sessions_per_worker`` connect/query/
    disconnect cycles — ~concurrency x sessions_per_worker simulated
    clients total.  Every response is validated against the differential
    oracle."""
    errors: list[str] = []
    lats: list[float] = []
    lock = threading.Lock()

    def worker(wid: int) -> None:
        rng = np.random.default_rng(1000 + wid)
        my_lats, my_errs = [], []
        for s in range(sessions_per_worker):
            sock = socket.create_connection((srv.host, srv.port))
            f = sock.makefile("rw", encoding="utf-8", newline="\n")
            for i in range(reqs_per_session):
                t = _draw(times, rng)
                rid = f"w{wid}s{s}r{i}t{t}"
                t0 = time.perf_counter()
                f.write(json.dumps({"kind": "snapshot", "t": t,
                                    "id": rid}) + "\n")
                f.flush()
                env = json.loads(f.readline())
                my_lats.append(time.perf_counter() - t0)
                err = _check(env, rid, oracle)
                if err:
                    my_errs.append(err)
            f.close()
            sock.close()
        with lock:
            lats.extend(my_lats)
            errors.extend(my_errs)

    threads = [threading.Thread(target=worker, args=(w,))
               for w in range(concurrency)]
    t0 = time.perf_counter()
    for th in threads:
        th.start()
    for th in threads:
        th.join()
    wall = time.perf_counter() - t0
    arr = np.sort(np.asarray(lats)) * 1e3
    return {"requests": len(lats), "qps": len(lats) / wall,
            "wall_s": wall, "p50_ms": float(np.percentile(arr, 50)),
            "p99_ms": float(np.percentile(arr, 99)),
            "errors": errors[:10], "n_errors": len(errors),
            "sessions": concurrency * sessions_per_worker}


# ----------------------------------------------------------------- open loop


def _open_loop(srv: QueryServer, times: np.ndarray, oracle: dict, *,
               rate_qps: float, duration_s: float,
               connections: int = 8) -> dict:
    """Paced open-loop traffic: senders fire pipelined requests at a
    global target rate regardless of completions; per-connection readers
    record latencies.  Admitted (ok) and shed (overloaded) envelopes are
    tallied separately — the SLO story is the p99 of the *admitted*."""
    stop = threading.Event()
    ok_lats: list[float] = []
    shed = [0]
    errors: list[str] = []
    lock = threading.Lock()

    def connection(cid: int) -> None:
        sock = socket.create_connection((srv.host, srv.port))
        f = sock.makefile("rw", encoding="utf-8", newline="\n")
        sent: dict[str, float] = {}
        pending = []
        rng = np.random.default_rng(2000 + cid)
        per_conn = rate_qps / connections
        gap = 1.0 / max(per_conn, 1e-9)
        n = 0
        t_start = time.perf_counter()

        def drain(block: bool) -> None:
            while pending:
                if not block:
                    # only reap what is already buffered
                    sock.setblocking(False)
                    try:
                        peek = f.readline()
                    except (BlockingIOError, OSError):
                        sock.setblocking(True)
                        return
                    sock.setblocking(True)
                else:
                    peek = f.readline()
                if not peek:
                    return
                env = json.loads(peek)
                rid = pending.pop(0)
                now = time.perf_counter()
                with lock:
                    if env.get("id") != rid:
                        errors.append(f"cross-wired {rid}")
                    elif env.get("ok"):
                        ok_lats.append(now - sent[rid])
                    elif env["error"]["kind"] in ("overloaded",
                                                  "deadline"):
                        shed[0] += 1
                    else:
                        errors.append(f"{rid}: {env['error']}")

        while not stop.is_set():
            t = _draw(times, rng)
            rid = f"c{cid}n{n}t{t}"
            sent[rid] = time.perf_counter()
            pending.append(rid)
            f.write(json.dumps({"kind": "snapshot", "t": t,
                                "id": rid}) + "\n")
            f.flush()
            n += 1
            drain(block=False)
            sleep = t_start + n * gap - time.perf_counter()
            if sleep > 0:
                time.sleep(sleep)
        drain(block=True)
        f.close()
        sock.close()

    threads = [threading.Thread(target=connection, args=(c,))
               for c in range(connections)]
    for th in threads:
        th.start()
    time.sleep(duration_s)
    stop.set()
    for th in threads:
        th.join(timeout=60)
    arr = (np.sort(np.asarray(ok_lats)) * 1e3 if ok_lats
           else np.asarray([float("inf")]))
    total = len(ok_lats) + shed[0]
    return {"offered_qps": rate_qps, "admitted": len(ok_lats),
            "shed": shed[0],
            "shed_frac": shed[0] / max(total, 1),
            "admitted_p50_ms": float(np.percentile(arr, 50)),
            "admitted_p99_ms": float(np.percentile(arr, 99)),
            "errors": errors[:10], "n_errors": len(errors)}


# ------------------------------------------------------------------ deadlines


def _deadline_probe(gm, srv: QueryServer, times: np.ndarray) -> dict:
    """Fire expired-deadline requests at an idle server: every one must
    come back as a typed ``deadline`` envelope with zero KV gets."""
    sock = socket.create_connection((srv.host, srv.port))
    f = sock.makefile("rw", encoding="utf-8", newline="\n")
    g0 = gm.store.stats.gets
    n = 50
    rejected = 0
    for i in range(n):
        t = int(times[i % times.size])
        env_ = {"kind": "snapshot", "t": t, "deadline_ms": 1e-4,
                "id": f"d{i}"}
        f.write(json.dumps(env_) + "\n")
        f.flush()
        env = json.loads(f.readline())
        rejected += (not env["ok"]
                     and env["error"]["kind"] == "deadline")
    gets = gm.store.stats.gets - g0
    f.close()
    sock.close()
    return {"requests": n, "rejected": rejected, "kv_gets": int(gets)}


# ------------------------------------------------------------------ the bench


def bench_server(quick: bool = False):
    n_events = 3_000 if quick else 10_000
    concurrency = 16 if quick else 32
    spw = 4 if quick else 32          # sessions per worker (~1k total full)
    rps = 6                           # requests per session
    open_s = 2.0 if quick else 5.0

    gm = _build(n_events)
    times = _zipf_times(int(gm.epochs.current_data.max_time))
    oracle = _oracle(gm, times)
    report: dict = {"n_events": n_events,
                    "kv_get_latency_us": GET_LATENCY_US,
                    "zipf": ZIPF, "distinct_times": DISTINCT_TIMES,
                    "workers": WORKERS, "window_ms": WINDOW_MS}

    # ---- closed loop: co-batching window vs window=0, equal KV budget --
    closed = {}
    for label, window in (("window0", 0.0), ("cobatch", WINDOW_MS)):
        with QueryServer(gm, window_ms=window, workers=WORKERS,
                         admit_horizon_ms=0.0) as srv:
            closed[label] = _closed_loop(
                srv, times, oracle, concurrency=concurrency,
                sessions_per_worker=spw, reqs_per_session=rps)
            closed[label]["scheduler"] = srv.scheduler.snapshot_stats()
    report["closed_loop"] = closed
    speedup = closed["cobatch"]["qps"] / closed["window0"]["qps"]
    report["cobatch_speedup"] = speedup

    # ---- open loop: probe capacity, then 0.5x vs 2x ---------------------
    # capacity is the sustained *admitted* rate under a deliberate
    # overload (closed-loop QPS is latency-bound, not the ceiling)
    with QueryServer(gm, window_ms=WINDOW_MS, workers=WORKERS,
                     admit_horizon_ms=ADMIT_MS) as srv:
        probe = _open_loop(srv, times, oracle,
                           rate_qps=6.0 * closed["cobatch"]["qps"],
                           duration_s=open_s)
    capacity = probe["admitted"] / open_s
    report["capacity_probe"] = {**probe, "capacity_qps": capacity}

    open_runs = {}
    for label, frac in (("half_capacity", 0.5), ("twice_capacity", 2.0)):
        with QueryServer(gm, window_ms=WINDOW_MS, workers=WORKERS,
                         admit_horizon_ms=ADMIT_MS) as srv:
            open_runs[label] = _open_loop(
                srv, times, oracle, rate_qps=capacity * frac,
                duration_s=open_s)
            open_runs[label]["admit_horizon_ms"] = ADMIT_MS
    report["open_loop"] = open_runs
    pre_p99 = open_runs["half_capacity"]["admitted_p99_ms"]
    sat = open_runs["twice_capacity"]

    # ---- deadlines ------------------------------------------------------
    with QueryServer(gm, window_ms=WINDOW_MS, workers=WORKERS) as srv:
        report["deadline"] = _deadline_probe(gm, srv, times)

    wiring_errors = (closed["window0"]["n_errors"]
                     + closed["cobatch"]["n_errors"]
                     + probe["n_errors"]
                     + sum(r["n_errors"] for r in open_runs.values()))
    report["gates"] = {
        "cobatch_qps": speedup >= 1.5,
        "p99_bounded": (sat["admitted_p99_ms"] < 3.0 * pre_p99
                        and sat["shed"] > 0),
        "deadline_no_kv": (report["deadline"]["kv_gets"] == 0
                           and report["deadline"]["rejected"]
                           == report["deadline"]["requests"]),
        "no_cross_wiring": wiring_errors == 0,
    }
    gm.close()

    with open(OUT_JSON, "w") as f:
        json.dump(report, f, indent=2, sort_keys=True)

    us = 1e6 / max(closed["cobatch"]["qps"], 1e-9)
    yield ("server_closed_loop", us,
           {"json": OUT_JSON, "qps_cobatch": round(closed["cobatch"]["qps"]),
            "qps_window0": round(closed["window0"]["qps"]),
            "speedup": round(speedup, 2),
            "sessions": closed["cobatch"]["sessions"],
            **report["gates"]})
    yield ("server_open_loop_2x",
           sat["admitted_p99_ms"] * 1e3,
           {"admitted_p99_ms": round(sat["admitted_p99_ms"], 2),
            "pre_p99_ms": round(pre_p99, 2),
            "shed_frac": round(sat["shed_frac"], 3)})


# --------------------------------------------------------------------- smoke


def smoke() -> int:
    """CI: boot the socket server, 200-request mixed Zipf burst over
    concurrent sessions, every envelope valid, no leaked threads/fds."""
    import os

    gm = _build(2_000)
    times = _zipf_times(int(gm.epochs.current_data.max_time))
    oracle = _oracle(gm, times)
    fd_dir = "/proc/self/fd"
    have_fds = os.path.isdir(fd_dir)
    threads0 = threading.active_count()
    fds0 = len(os.listdir(fd_dir)) if have_fds else 0

    srv = QueryServer(gm, window_ms=WINDOW_MS, workers=2).start()
    res = _closed_loop(srv, times, oracle, concurrency=8,
                       sessions_per_worker=5, reqs_per_session=5)
    dl = _deadline_probe(gm, srv, times)
    stats = srv.scheduler.snapshot_stats()
    srv.close()
    gm.close()  # kv-prefetch workers spawn lazily mid-burst; close before
    time.sleep(0.3)  # sampling or they read as a server leak

    threads1 = threading.active_count()
    fds1 = len(os.listdir(fd_dir)) if have_fds else 0

    failures = []
    if res["n_errors"]:
        failures.append(f"invalid envelopes: {res['errors']}")
    if res["requests"] != 200:
        failures.append(f"expected 200 requests, ran {res['requests']}")
    if dl["kv_gets"] != 0 or dl["rejected"] != dl["requests"]:
        failures.append(f"deadline probe: {dl}")
    if threads1 > threads0:
        failures.append(f"leaked threads: {threads0} -> {threads1}")
    if have_fds and fds1 > fds0:
        failures.append(f"leaked fds: {fds0} -> {fds1}")
    print(json.dumps({"requests": res["requests"], "qps": round(res["qps"]),
                      "co_batched_docs": stats["co_batched_docs"],
                      "deadline": dl, "threads": [threads0, threads1],
                      "fds": [fds0, fds1]}, sort_keys=True))
    if failures:
        print("SMOKE_FAIL " + "; ".join(failures))
        return 1
    print("SMOKE_OK")
    return 0


if __name__ == "__main__":
    import argparse

    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true")
    ap.add_argument("--smoke", action="store_true")
    args = ap.parse_args()
    if args.smoke:
        raise SystemExit(smoke())
    for name, us, derived in bench_server(quick=args.quick):
        print(f"{name},{us:.1f},{json.dumps(derived)}")
